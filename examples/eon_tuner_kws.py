"""EON Tuner demo (paper §4.7 / Table 3): AutoML over the joint
(DSP × NN) space under MCU resource constraints.

Run:  PYTHONPATH=src python examples/eon_tuner_kws.py
"""
import numpy as np

from repro.core.tuner import EONTuner
from repro.data.dataset import Dataset
from repro.data.synthetic import keyword_audio

N_SAMPLES = 8000


def main():
    ds = Dataset()
    ds.add_many(keyword_audio(n_per_class=24, n_classes=4,
                              n_samples=N_SAMPLES))
    xtr, ytr = ds.arrays("train")
    xva, yva = ds.arrays("val")

    tuner = EONTuner(input_samples=N_SAMPLES, n_classes=4,
                     target="nano33ble", max_latency_ms=400, seed=0)
    cands = tuner.sample(10)
    print(f"sampled {len(cands)} configurations")
    survivors = tuner.screen(cands)
    print(f"{len(survivors)} pass the nano33ble RAM/flash/latency screen "
          f"(the paper's cheap-heuristic phase)")
    ranked = tuner.evaluate(survivors, (np.asarray(xtr), np.asarray(ytr)),
                            (np.asarray(xva), np.asarray(yva)), epochs=3)
    print(f"\n{'configuration':<46}{'acc':>5} {'dsp':>7} {'nn':>7} "
          f"{'ram':>7} {'flash':>8}")
    for c in ranked:
        e = c.estimate
        print(f"{c.describe():<46}{c.accuracy:5.2f} "
              f"{e.dsp_latency_ms:6.0f}m {e.nn_latency_ms:6.1f}m "
              f"{e.ram_kb:6.1f}k {e.flash_kb:7.1f}k")


if __name__ == "__main__":
    main()
