"""Active-learning loop demo (paper §4.8): train on a small labeled
subset, embed everything, auto-label by cluster proximity, retrain.

Run:  PYTHONPATH=src python examples/active_learning_demo.py
"""
import jax
import numpy as np

from repro.core.active_learning import active_learning_round
from repro.core.blocks import make_dsp_block, make_learn_block
from repro.core.impulse import Impulse
from repro.data.dataset import Dataset
from repro.data.synthetic import keyword_audio

N_SAMPLES = 8000
N_CLASSES = 4


def main():
    ds = Dataset()
    ds.add_many(keyword_audio(n_per_class=30, n_classes=N_CLASSES,
                              n_samples=N_SAMPLES))
    xs, ys = ds.arrays("train")
    xs, ys = np.asarray(xs), np.asarray(ys)

    # 1. label only 6 samples per class
    labeled_idx = np.concatenate(
        [np.where(ys == c)[0][:6] for c in range(N_CLASSES)])
    print(f"labeled subset: {len(labeled_idx)}/{len(xs)} samples")

    imp = Impulse(make_dsp_block("mfcc", n_mels=32, n_coeffs=10),
                  make_learn_block("conv1d-stack", n_blocks=2, ch_first=16,
                                   ch_last=32, n_classes=N_CLASSES),
                  input_shape=N_SAMPLES)
    imp.init(jax.random.key(0))
    imp.fit((xs[labeled_idx], ys[labeled_idx]), epochs=8, batch_size=8,
            lr=2e-3)

    # 2-4. embed (features as the intermediate layer), project, propose
    out = active_learning_round(
        lambda x: np.asarray(imp.features(x)).reshape(len(x), -1),
        xs, labeled_idx, ys, N_CLASSES)
    prop, conf = out["proposed"], out["confident"]
    mask = conf & (prop >= 0)
    acc = float((prop[mask] == ys[mask]).mean())
    print(f"auto-labeled {int(mask.sum())} samples at {acc:.2%} accuracy "
          f"(PCA explained variance: {out['explained_variance']})")

    # 5. retrain on the expanded label set
    keep = mask | np.isin(np.arange(len(xs)), labeled_idx)
    imp2 = Impulse(imp.dsp, imp.learn, input_shape=N_SAMPLES)
    imp2.init(jax.random.key(1))
    imp2.fit((xs[keep], prop[keep]), epochs=6, batch_size=16, lr=2e-3)
    xte, yte = ds.arrays("test")
    small = imp.evaluate(imp.params, np.asarray(xte), np.asarray(yte))
    grown = imp2.evaluate(imp2.params, np.asarray(xte), np.asarray(yte))
    print(f"test acc: {small:.2%} (labeled subset only) -> "
          f"{grown:.2%} (after active-learning expansion)")


if __name__ == "__main__":
    main()
