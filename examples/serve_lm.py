"""Batched serving example: submit prompts to the BatchServer (the EIM
process-runner analogue) and report TTFT / throughput.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch zamba2-2.7b
"""
import argparse
import json

import jax
import numpy as np

from repro import configs
from repro.models.params import init_params
from repro.serve.server import BatchServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=list(configs.ALIASES))
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=12)
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)   # reduced config on CPU
    params = init_params(cfg, jax.random.key(0))
    server = BatchServer(cfg, params, batch_size=args.batch,
                         prompt_len=args.prompt_len,
                         max_new_tokens=args.max_new)
    rng = np.random.RandomState(0)
    reqs = server.submit([
        rng.randint(0, cfg.vocab_size, args.prompt_len).astype(np.int32)
        for _ in range(args.requests)])
    metrics = server.run()
    print(json.dumps(metrics, indent=1))
    print("first request generated:", reqs[0].tokens)


if __name__ == "__main__":
    main()
