"""Batched serving example: submit mixed-length prompts to the
continuous-batching server (the EIM process-runner analogue, paper §4.6)
and report TTFT / throughput.

Run:  PYTHONPATH=src python examples/serve_lm.py --arch zamba2-2.7b
"""
import argparse
import json

import jax
import numpy as np

from repro import configs
from repro.models.params import init_params
from repro.serve.server import ContinuousBatchServer, StaticBatchServer


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b",
                    choices=list(configs.ALIASES))
    ap.add_argument("--engine", choices=("continuous", "static"),
                    default="continuous")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", "--batch", dest="slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--precision", choices=("float", "int8"),
                    default="float")
    args = ap.parse_args()

    cfg = configs.get_smoke(args.arch)   # reduced config on CPU
    params = init_params(cfg, jax.random.key(0))
    if args.engine == "static":
        server = StaticBatchServer(cfg, params, batch_size=args.slots,
                                   max_prompt=args.prompt_len,
                                   max_new_tokens=args.max_new,
                                   precision=args.precision)
    else:
        server = ContinuousBatchServer(
            cfg, params, slots=args.slots, max_prompt=args.prompt_len,
            max_new_tokens=args.max_new, precision=args.precision)
    rng = np.random.RandomState(0)
    # mixed-length workload: short and long prompts, varied budgets
    lens = [rng.randint(4, args.prompt_len + 1) for _ in range(args.requests)]
    budgets = [int(rng.randint(2, args.max_new + 1))
               for _ in range(args.requests)]
    reqs = server.submit(
        [rng.randint(0, cfg.vocab_size, n).astype(np.int32) for n in lens],
        max_new_tokens=budgets)
    metrics = server.run()
    print(json.dumps(metrics, indent=1))
    print("first request generated:", reqs[0].tokens)


if __name__ == "__main__":
    main()
