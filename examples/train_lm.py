"""End-to-end training driver: a ~100M-param LM for a few hundred steps.

Exercises the full pod substrate on CPU scale: data pipeline → grad-
accumulation train step → AdamW → checkpoints → crash-safe resume →
best-model restore.  The identical step function is what the dry-run
lowers for the 256/512-chip meshes.

Run:  PYTHONPATH=src python examples/train_lm.py --steps 150
      (--d-model 768 --layers 12 reaches ~106M params; the default is a
       ~60M config sized for a single-core CPU budget)
"""
import argparse
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.core.arch import ArchConfig
from repro.data.synthetic import lm_batches, token_stream
from repro.models.params import init_params, param_count
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=150)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=12)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--micro", type=int, default=2)
    ap.add_argument("--lr", type=float, default=6e-4)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--out", default="experiments/train_lm.json")
    args = ap.parse_args()

    cfg = ArchConfig(
        name="examples-lm", family="dense",
        n_layers=args.layers, d_model=args.d_model,
        n_heads=args.d_model // 64, n_kv_heads=args.d_model // 128,
        d_ff=4 * args.d_model, vocab_size=args.vocab,
        vocab_pad_multiple=256)
    params = init_params(cfg, jax.random.key(0))
    n = param_count(cfg)
    print(f"model: {args.layers}L d={args.d_model} -> {n/1e6:.1f}M params")

    tokens = token_stream(400_000, cfg.vocab_size, seed=1)
    batches = lm_batches(tokens, args.batch, args.seq)
    step = jax.jit(make_train_step(cfg, n_microbatch=args.micro,
                                   remat="none",
                                   opt=AdamWConfig(lr=args.lr)),
                   donate_argnums=(0, 1))
    trainer = Trainer(step, params, adamw_init(params),
                      ckpt_dir=Path(args.ckpt_dir),
                      config=TrainerConfig(total_steps=args.steps,
                                           checkpoint_every=50,
                                           log_every=10))
    if args.resume and trainer.maybe_resume():
        print(f"resumed at step {trainer.step}")
    t0 = time.time()
    result = trainer.run(iter(batches))
    dt = time.time() - t0
    toks = args.steps * args.batch * args.seq
    summary = {
        "params_m": n / 1e6, "steps": args.steps,
        "first_loss": result["history"][0]["loss"],
        "final_loss": result["final_loss"],
        "best": result["best"],
        "tokens_per_s": toks / dt,
        "unigram_entropy_bound": float(np.log(args.vocab)),
    }
    print(json.dumps(summary, indent=1))
    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    Path(args.out).write_text(json.dumps(
        {**summary, "history": result["history"]}, indent=1))


if __name__ == "__main__":
    main()
