"""Quickstart: the paper's end-to-end loop in one script.

data collection (synthetic keyword audio) → versioned dataset → Impulse
(MFCC DSP block + conv1d model block) → train → evaluate (confusion
matrix) → int8 quantize → per-target resource estimation → EON-compile
to a serialized artifact → performance-calibrate the post-processing.

Run:  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax

from repro.core import estimator as est
from repro.core.blocks import make_dsp_block, make_learn_block
from repro.core.calibration import calibrate
from repro.core.eon_compiler import compile_impulse
from repro.core.impulse import Impulse
from repro.data.dataset import Dataset
from repro.data.synthetic import event_stream, keyword_audio

N_SAMPLES = 8000
N_CLASSES = 4


def main():
    # 1. data: collect + version
    ds = Dataset()
    ds.add_many(keyword_audio(n_per_class=24, n_classes=N_CLASSES,
                              n_samples=N_SAMPLES))
    version = ds.commit("synthetic keywords v1")
    print(f"dataset {version}: {len(ds)} samples, "
          f"classes={ds.class_counts()}")

    # 2. impulse: DSP block + learn block
    imp = Impulse(make_dsp_block("mfcc", n_mels=32, n_coeffs=10),
                  make_learn_block("conv1d-stack", n_blocks=2, ch_first=16,
                                   ch_last=64, n_classes=N_CLASSES),
                  input_shape=N_SAMPLES)
    imp.init(jax.random.key(0))

    # 3. train + evaluate
    xtr, ytr = ds.arrays("train")
    xte, yte = ds.arrays("test")
    imp.fit((np.asarray(xtr), np.asarray(ytr)), epochs=6, batch_size=16,
            lr=2e-3, log_every=2)
    acc = imp.evaluate(imp.params, np.asarray(xte), np.asarray(yte))
    print(f"float test accuracy: {acc:.3f}")
    print("confusion matrix:\n",
          imp.confusion_matrix(np.asarray(xte), np.asarray(yte), N_CLASSES))

    # 4. quantize (paper C5)
    imp.quantize(np.asarray(xtr[:16]))
    acc8 = imp.int8_accuracy(np.asarray(xte), np.asarray(yte))
    print(f"int8 test accuracy: {acc8:.3f} "
          f"(weights {imp.qparams.meta['compression']:.1f}x smaller)")

    # 5. estimate per target (paper C2)
    for target in est.TARGETS:
        e = est.estimate_impulse(imp, target, engine="eon", int8=True)
        print(f"{target:10s}: dsp={e.dsp_latency_ms:6.1f}ms "
              f"nn={e.nn_latency_ms:5.1f}ms ram={e.ram_kb:6.1f}kB "
              f"flash={e.flash_kb:6.1f}kB fits={e.fits}")

    # 6. EON-compile: interpreter-less deployment artifact (paper C4)
    art = compile_impulse(imp, batch_size=1, int8=True)
    print(f"deploy artifact: {art.artifact_bytes} bytes, "
          f"compile {art.compile_time_s:.1f}s")

    # 7. performance calibration (paper C6)
    scores, spans = event_stream(n_windows=10_000, n_events=40)
    front = calibrate(scores, spans, generations=8, population=20)
    print("post-processing Pareto front (FAR/h vs FRR):")
    for p in front[:5]:
        print(f"  far={p['far_per_hour']:6.1f}/h frr={p['frr']:.3f} "
              f"cfg={p['config']}")


if __name__ == "__main__":
    main()
