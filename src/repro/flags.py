"""Performance flags (the §Perf hillclimb levers, default-off so the
recorded baseline matrix stays reproducible).

bf16_params  : cast float32 master weights to bf16 once at step entry —
               FSDP all-gathers and the embed-table gather then move
               half the bytes (measured: llama3.2 train collective term
               -44%).  Grads still flow to f32 masters (mixed precision).
bf16_attn_p  : consume softmax probabilities in bf16 in the chunked-
               attention pv matmul (flash kernels do this on the MXU);
               accumulators stay f32.
kernel_path  : pin every ``kernels/ops.py`` dispatch to one backend
               ("pallas" | "interpret" | "ref"); None means the default
               backend probe (pallas on TPU, ref elsewhere).  Seeded
               from $REPRO_KERNEL_PATH so CI can exercise the Pallas
               interpret path suite-wide without touching call sites.
               Read when a function traces: set it *before* the first
               call of any jitted function you want pinned — an
               already-compiled executable keeps the backend it traced
               with.
"""
from __future__ import annotations

import os

_KERNEL_PATHS = (None, "pallas", "interpret", "ref")


def _env_kernel_path():
    path = os.environ.get("REPRO_KERNEL_PATH") or None
    if path not in _KERNEL_PATHS:
        raise ValueError(
            f"REPRO_KERNEL_PATH={path!r}: expected one of "
            f"{[p for p in _KERNEL_PATHS if p]}")
    return path


FLAGS = {
    "bf16_params": False,
    "bf16_attn_p": False,
    "kernel_path": _env_kernel_path(),
}


def set_flags(**kw) -> None:
    for k, v in kw.items():
        if k not in FLAGS:
            raise KeyError(k)
        if k == "kernel_path" and v not in _KERNEL_PATHS:
            raise ValueError(f"kernel_path={v!r}")
        FLAGS[k] = v


def get(name: str):
    return FLAGS[name]
