"""Performance flags (the §Perf hillclimb levers, default-off so the
recorded baseline matrix stays reproducible).

bf16_params  : cast float32 master weights to bf16 once at step entry —
               FSDP all-gathers and the embed-table gather then move
               half the bytes (measured: llama3.2 train collective term
               -44%).  Grads still flow to f32 masters (mixed precision).
bf16_attn_p  : consume softmax probabilities in bf16 in the chunked-
               attention pv matmul (flash kernels do this on the MXU);
               accumulators stay f32.
"""
from __future__ import annotations

FLAGS = {
    "bf16_params": False,
    "bf16_attn_p": False,
}


def set_flags(**kw) -> None:
    for k, v in kw.items():
        if k not in FLAGS:
            raise KeyError(k)
        FLAGS[k] = v


def get(name: str) -> bool:
    return FLAGS[name]
