"""Compiled-HLO analysis: loop-weighted FLOPs, HBM bytes, collective traffic.

``compiled.cost_analysis()`` reports each while-loop body ONCE (verified:
a 10-iteration scan reports the same flops as a single iteration), which
silently undercounts every scanned layer stack by its depth.  So we walk
the compiled module's computation graph ourselves:

* **dot FLOPs** — 2 · |result| · K from each ``dot`` line (operand shapes
  resolved through a per-computation symbol table),
* **HBM bytes** — operand + result bytes of every top-level op at fusion
  granularity (fusion internals stay in registers/VMEM — the fusion
  boundary is the HBM traffic model),
* **collectives** — result-shape bytes per op with ring-schedule
  per-device traffic derived from the replica-group size,

then weight every while body by its trip count (``known_trip_count``
backend_config, falling back to the scan condition's compare constant)
and accumulate recursively from ENTRY.
"""
from __future__ import annotations

import re
from collections import defaultdict
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Set, Tuple

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16, "u4": 1, "s4": 1,
}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w\.\-]+)\s+\((.*)\)\s+->\s+.*\{$")
# shape strings may contain `/*index=N*/` comments; the op name is the
# earliest `token(` after the `=` (shapes/comments never form `word(`).
_OP_RE = re.compile(r"^(?:ROOT\s+)?%([\w\.\-]+)\s+=\s+(.*?)\s+([\w\-]+)\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([\d,]*)\]")
_OPERAND_RE = re.compile(r"%([\w\.\-]+)")
_GROUP_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUP_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_WHILE_RE = re.compile(
    r"\bwhile\(.*?condition=%?([\w\.\-]+),\s*body=%?([\w\.\-]+)")
_FUSION_CALLS_RE = re.compile(r"calls=%?([\w\.\-]+)")
_COND_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_CONST_RE = re.compile(r"s32\[\]\s+constant\((\d+)\)")
_TRIP_RE = re.compile(r'known_trip_count[^\d]*(\d+)')
_LHS_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

_SKIP_BYTES_OPS = {
    "parameter", "tuple", "get-tuple-element", "bitcast", "constant",
    "after-all", "iota", "partition-id", "replica-id", "rng-bit-generator",
}


def _shape_list_bytes(shape_str: str) -> int:
    """Total bytes of a (possibly tuple) shape string."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES.get(dtype, 4)
    return total


def _shape_dims(shape_str: str) -> Tuple[List[int], str]:
    m = _SHAPE_RE.search(shape_str)
    if not m:
        return [], "f32"
    dims = [int(d) for d in m.group(2).split(",") if d]
    return dims, m.group(1)


@dataclass
class Computation:
    name: str
    is_entry: bool = False
    collectives: List[Tuple[str, int, int]] = field(default_factory=list)
    whiles: List[Tuple[str, str, Optional[int]]] = field(default_factory=list)
    calls: List[str] = field(default_factory=list)        # conditionals, calls
    fusion_callees: Set[str] = field(default_factory=set)
    consts: List[int] = field(default_factory=list)
    dot_flops: float = 0.0
    bytes_accessed: float = 0.0
    bytes_min: float = 0.0
    symbols: Dict[str, str] = field(default_factory=dict)  # name -> shape str


def parse_module(hlo_text: str) -> Tuple[Dict[str, Computation], str]:
    comps: Dict[str, Computation] = {}
    current: Optional[Computation] = None
    entry = ""
    pending_ops: List[Tuple[str, str, str, str]] = []

    def flush(comp: Computation, ops):
        # second pass per computation: operand shapes now all known
        for name, shape_str, opname, line in ops:
            if opname in _SKIP_BYTES_OPS:
                continue
            if opname in ("while", "conditional", "call"):
                continue  # handled via graph recursion
            nbytes = _shape_list_bytes(shape_str)
            paren = line.split("(", 1)[1] if "(" in line else ""
            args = paren.split(")", 1)[0]
            operand_shapes = [comp.symbols.get(o)
                              for o in _OPERAND_RE.findall(args)]
            operand_bytes = sum(_shape_list_bytes(s)
                                for s in operand_shapes if s)
            # Slice-family ops touch only the slice region, not the full
            # operand (which the naive operand+result sum would charge).
            if opname in ("dynamic-slice", "slice"):
                comp.bytes_accessed += 2 * nbytes
                comp.bytes_min += 2 * nbytes
            elif opname == "dynamic-update-slice":
                upd = (_shape_list_bytes(operand_shapes[1])
                       if len(operand_shapes) > 1 and operand_shapes[1]
                       else nbytes)
                comp.bytes_accessed += 2 * upd
                comp.bytes_min += 2 * upd
            elif opname == "gather":
                comp.bytes_accessed += 2 * nbytes
                comp.bytes_min += 2 * nbytes
            elif opname == "scatter":
                upd = (_shape_list_bytes(operand_shapes[2])
                       if len(operand_shapes) > 2 and operand_shapes[2]
                       else nbytes)
                comp.bytes_accessed += 3 * upd
                comp.bytes_min += 3 * upd
            else:
                comp.bytes_accessed += nbytes + operand_bytes
                # lower bound: only ops a TPU fusion pass cannot elide —
                # dots and collectives read/write HBM; elementwise chains
                # fuse into neighbours (the CPU backend's fusion
                # granularity inflates the upper bound 2-4x).
                if opname in ("dot", "convolution") or opname in COLLECTIVES:
                    comp.bytes_min += nbytes + operand_bytes
            if opname == "dot":
                dims, _ = _shape_dims(shape_str)
                result_elems = 1
                for d in dims:
                    result_elems *= d
                k = 1
                lhs_m = _OPERAND_RE.findall(args)
                lhs_shape = comps_local_shape(comp, lhs_m[0]) if lhs_m else []
                cm = _LHS_CONTRACT_RE.search(line)
                if cm and lhs_shape:
                    for idx in cm.group(1).split(","):
                        if idx and int(idx) < len(lhs_shape):
                            k *= lhs_shape[int(idx)]
                comp.dot_flops += 2.0 * result_elems * k

    def comps_local_shape(comp: Computation, op_name: str) -> List[int]:
        s = comp.symbols.get(op_name)
        if not s:
            return []
        return _shape_dims(s)[0]

    for raw in hlo_text.splitlines():
        line = raw.strip()
        m = _HDR_RE.match(line)
        if m:
            if current is not None:
                flush(current, pending_ops)
            current = Computation(m.group(2), is_entry=bool(m.group(1)))
            comps[current.name] = current
            if m.group(1):
                entry = current.name
            pending_ops = []
            # register parameters from the header signature
            for pm in re.finditer(r"([\w\.\-]+):\s+(\(?[a-z0-9]+\[[^)]*?\])",
                                  m.group(3)):
                current.symbols[pm.group(1)] = pm.group(2)
            continue
        if line == "}":
            if current is not None:
                flush(current, pending_ops)
                pending_ops = []
            current = None
            continue
        if current is None:
            continue

        om = _OP_RE.match(line)
        if om:
            name, shape_str, opname = om.group(1), om.group(2), om.group(3)
            current.symbols[name] = shape_str
            if opname == "parameter":
                pass
            pending_ops.append((name, shape_str, opname, line))

            if opname in COLLECTIVES or any(
                    opname == c + "-start" for c in COLLECTIVES):
                base = opname.replace("-start", "")
                g = 1
                gi = _GROUP_IOTA_RE.search(line)
                if gi:
                    g = int(gi.group(2))
                else:
                    gl = _GROUP_LIST_RE.search(line)
                    if gl:
                        g = len(gl.group(1).split(","))
                current.collectives.append(
                    (base, _shape_list_bytes(shape_str), g))
            elif opname == "while":
                wm = _WHILE_RE.search(line)
                tm = _TRIP_RE.search(line)
                if wm:
                    current.whiles.append(
                        (wm.group(1), wm.group(2),
                         int(tm.group(1)) if tm else None))
            elif opname == "fusion":
                fm = _FUSION_CALLS_RE.search(line)
                if fm:
                    current.fusion_callees.add(fm.group(1))
            elif opname == "conditional":
                bm = _COND_BRANCH_RE.search(line)
                if bm:
                    current.calls.extend(
                        c.strip().lstrip("%") for c in bm.group(1).split(","))
            elif opname == "call":
                fm = _FUSION_CALLS_RE.search(line)
                if fm:
                    current.calls.append(fm.group(1))
        for c in _CONST_RE.findall(line):
            current.consts.append(int(c))
    if current is not None:
        flush(current, pending_ops)
    return comps, entry


@dataclass
class WeightedCosts:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    bytes_min: float = 0.0
    collectives: Dict[str, Dict[str, float]] = field(
        default_factory=lambda: defaultdict(
            lambda: {"count": 0.0, "bytes": 0.0, "ring_bytes": 0.0}))

    def add(self, other: "WeightedCosts", w: float = 1.0):
        self.flops += other.flops * w
        self.bytes_accessed += other.bytes_accessed * w
        self.bytes_min += other.bytes_min * w
        for kind, rec in other.collectives.items():
            mine = self.collectives[kind]
            for k in rec:
                mine[k] += rec[k] * w


def _ring_bytes(kind: str, nbytes: int, g: int) -> float:
    if g <= 1:
        return 0.0
    if kind == "all-reduce":
        return 2.0 * nbytes * (g - 1) / g
    if kind in ("all-gather", "all-to-all"):
        return nbytes * (g - 1) / g
    if kind == "reduce-scatter":
        return float(nbytes) * (g - 1)   # result shape is the shard
    return float(nbytes)                 # collective-permute


def _trip_count(comps, cond_name: str, known: Optional[int]) -> int:
    if known:
        return known
    cond = comps.get(cond_name)
    if cond is None or not cond.consts:
        return 1
    return max(cond.consts)


def analyze_module(hlo_text: str) -> WeightedCosts:
    comps, entry = parse_module(hlo_text)

    # computations reached only as fusion callees contribute no HBM bytes;
    # their cost is modeled at the fusion call site.
    fusion_only: Set[str] = set()
    for c in comps.values():
        fusion_only |= c.fusion_callees

    memo: Dict[str, WeightedCosts] = {}

    def visit(name: str, stack=()) -> WeightedCosts:
        if name in memo:
            return memo[name]
        if name in stack or name not in comps:
            return WeightedCosts()
        comp = comps[name]
        total = WeightedCosts()
        total.flops += comp.dot_flops
        total.bytes_accessed += comp.bytes_accessed
        total.bytes_min += comp.bytes_min
        for kind, nbytes, g in comp.collectives:
            rec = total.collectives[kind]
            rec["count"] += 1
            rec["bytes"] += nbytes
            rec["ring_bytes"] += _ring_bytes(kind, nbytes, g)
        for callee in comp.calls:
            total.add(visit(callee, stack + (name,)))
        for cond, body, known in comp.whiles:
            trip = _trip_count(comps, cond, known)
            total.add(visit(body, stack + (name,)), w=trip)
        memo[name] = total
        return total

    if not entry:
        return WeightedCosts()
    return visit(entry)


# ---------------------------------------------------------------------------
# Back-compat helpers
# ---------------------------------------------------------------------------
def collect_collectives(hlo_text: str) -> Dict[str, Dict[str, float]]:
    wc = analyze_module(hlo_text)
    return {k: dict(v) for k, v in wc.collectives.items()}


def total_collective_bytes(colls: Dict[str, Dict[str, float]],
                           key: str = "ring_bytes") -> float:
    return sum(v[key] for v in colls.values())


def scan_trip_counts(hlo_text: str) -> List[int]:
    comps, _ = parse_module(hlo_text)
    out = []
    for comp in comps.values():
        for cond, _body, known in comp.whiles:
            out.append(_trip_count(comps, cond, known))
    return out
