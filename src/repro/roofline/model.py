"""Three-term roofline from the compiled dry-run artifact.

    compute   = HLO_FLOPs / (chips × peak_FLOP/s)
    memory    = HLO_bytes / (chips × HBM_bw)
    collective= collective_bytes / (chips × link_bw)

``cost_analysis()`` on an SPMD executable reports *per-device* flops and
bytes (verified empirically in tests/test_roofline.py), so `chips` is
already divided out of the first two terms; the collective term uses the
per-device ring bytes from collect.py over the aggregate ICI injection
bandwidth.  MODEL_FLOPS / HLO_FLOPs measures how much compiled compute is
"useful" (catches remat recompute and padding waste).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional

from repro.core.arch import ArchConfig, ShapeConfig
from repro.roofline.hw import ChipModel, V5E


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    n_chips: int
    # raw per-device measurements
    hlo_flops: float
    hlo_bytes: float
    collective_bytes: float          # ring bytes per device
    collective_detail: Dict[str, Dict[str, float]]
    per_device_hbm: float            # bytes (args + temps + outputs)
    hlo_bytes_min: float = 0.0       # dots/collectives/slices only
    # derived
    t_compute: float = 0.0
    t_memory: float = 0.0            # upper bound (CPU fusion granularity)
    t_memory_min: float = 0.0        # lower bound (unfusable traffic)
    t_collective: float = 0.0
    bottleneck: str = ""
    model_flops: float = 0.0
    useful_flops_ratio: float = 0.0
    roofline_fraction: float = 0.0
    fits_hbm: bool = True
    note: str = ""

    def finalize(self, chip: ChipModel = V5E) -> "RooflineReport":
        self.t_compute = self.hlo_flops / chip.peak_flops_bf16
        self.t_memory = self.hlo_bytes / chip.hbm_bandwidth
        self.t_memory_min = self.hlo_bytes_min / chip.hbm_bandwidth
        self.t_collective = self.collective_bytes / chip.ici_bandwidth
        # Bottleneck is judged against the memory LOWER bound: the upper
        # bound (CPU-backend fusion granularity) inflates elementwise
        # traffic a TPU fusion pass would elide, which would mislabel
        # every cell memory-bound.
        terms = {"compute": self.t_compute, "memory": self.t_memory_min,
                 "collective": self.t_collective}
        self.bottleneck = max(terms, key=terms.get)
        if self.hlo_flops > 0:
            self.useful_flops_ratio = (
                self.model_flops / self.n_chips / self.hlo_flops)
        t_total = max(self.t_compute, self.t_memory_min, self.t_collective)
        if t_total > 0 and self.model_flops > 0:
            # fraction of chip peak achieved on *useful* model flops
            self.roofline_fraction = (
                self.model_flops / self.n_chips / t_total
                / chip.peak_flops_bf16)
        self.fits_hbm = self.per_device_hbm <= chip.hbm_bytes
        return self

    def row(self) -> Dict[str, object]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.n_chips,
            "t_compute_s": round(self.t_compute, 6),
            "t_memory_s": round(self.t_memory, 6),
            "t_memory_min_s": round(self.t_memory_min, 6),
            "t_collective_s": round(self.t_collective, 6),
            "bottleneck": self.bottleneck,
            "useful_flops_ratio": round(self.useful_flops_ratio, 4),
            "roofline_fraction": round(self.roofline_fraction, 4),
            "hbm_gib": round(self.per_device_hbm / 2**30, 3),
            "fits_hbm": self.fits_hbm,
        }


def attention_score_traffic(cfg: ArchConfig, shape: ShapeConfig,
                            n_chips: int) -> float:
    """Per-device HBM bytes of score-matrix dot I/O that the Pallas flash
    kernel keeps in VMEM (qk write + softmax read + p write + p read ≈ 16
    bytes/element in f32; backward ≈ 2 more passes for train).

    The jnp chunked-attention path necessarily round-trips the
    (B, H, Sq, chunk) tensors through HBM, so the dry-run memory term
    includes traffic the TPU kernel simply does not generate; this is
    the analytic credit (reported as *_fused roofline fields).
    """
    if not cfg.uses_attention or shape.kind == "decode":
        return 0.0
    b, s = shape.global_batch, shape.seq_len
    if cfg.is_encdec:
        s_enc = s // cfg.enc_seq_divisor
        elems = (cfg.n_enc_layers * s_enc * s_enc
                 + cfg.n_layers * (s * s + s * s_enc)) * b * cfg.n_heads
    elif cfg.family == "hybrid":
        n_attn = cfg.n_layers // max(cfg.attn_every, 1)
        elems = n_attn * b * cfg.n_heads * s * s
    elif cfg.local_global_ratio > 0:
        r = cfg.local_global_ratio
        n_groups = cfg.n_layers // (r + 1)
        n_global = n_groups
        n_local = cfg.n_layers - n_global
        w = cfg.sliding_window
        elems = b * cfg.n_heads * (n_global * s * s
                                   + n_local * s * min(2 * w, s))
    else:
        elems = cfg.n_layers * b * cfg.n_heads * s * s
    passes = 3.0 if shape.kind == "train" else 1.0
    return elems * 16.0 * passes / n_chips


def fused_adjustment(cfg: ArchConfig, shape: ShapeConfig,
                     rep: "RooflineReport",
                     chip: ChipModel = V5E) -> Dict[str, float]:
    """Roofline row with the flash-kernel VMEM credit applied."""
    credit = attention_score_traffic(cfg, shape, rep.n_chips)
    bytes_fused = max(rep.hlo_bytes_min - credit, 0.0)
    t_mem_fused = bytes_fused / chip.hbm_bandwidth
    t_total = max(rep.t_compute, t_mem_fused, rep.t_collective)
    frac = 0.0
    if t_total > 0 and rep.model_flops > 0:
        frac = (rep.model_flops / rep.n_chips / t_total
                / chip.peak_flops_bf16)
    return {"t_memory_min_fused_s": round(t_mem_fused, 6),
            "roofline_fraction_fused": round(frac, 4),
            "score_traffic_credit_bytes": credit}


def model_flops(cfg: ArchConfig, shape: ShapeConfig) -> float:
    """Useful model FLOPs for the step: 6·N·D train (3 passes of 2·N·D),
    2·N_active·D for inference; D = tokens processed this step."""
    n = cfg.param_count(active_only=False)
    n_active = cfg.param_count(active_only=True) if cfg.is_moe else n
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
