"""Target-hardware model: TPU v5e chip (the 'target device database').

The Edge Impulse analogue: the platform holds a per-target model (clock,
SRAM, flash for a Cortex-M; the triple below for a v5e chip) and scores
candidate deployments against it *before* touching hardware.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ChipModel:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12        # FLOP/s per chip
    hbm_bandwidth: float = 819e9           # bytes/s per chip
    hbm_bytes: int = 16 * 1024 ** 3        # 16 GiB per chip
    ici_link_bandwidth: float = 50e9       # bytes/s per link (~50 GB/s)
    ici_links_per_chip: int = 4            # 2D torus on v5e
    dcn_bandwidth: float = 25e9            # bytes/s per host-ish (pod axis)
    vmem_bytes: int = 128 * 1024 ** 2      # ~128 MiB VMEM (v5e: 128MB)
    mxu_tile: int = 128                    # systolic array dim

    @property
    def ici_bandwidth(self) -> float:
        """Aggregate ICI injection bandwidth per chip."""
        return self.ici_link_bandwidth * self.ici_links_per_chip


V5E = ChipModel()

# int8 path (quantized serving — paper C5): v5e int8 peak is 394 TOPS.
V5E_INT8 = ChipModel(name="tpu-v5e-int8", peak_flops_bf16=394e12)
