"""Pallas TPU kernel: blocked int8×int8 matmul, dequant fused in epilogue.

The EON-quantization serving path (paper C5): weights and activations are
int8, the MXU runs the int8 systolic path (2× bf16 throughput on v5e),
and the per-channel dequant scales are applied once in the output
epilogue instead of materializing a dequantized weight matrix in HBM.

Blocking: (bm × bk) · (bk × bn) tiles staged in VMEM, K innermost so the
int32 accumulator lives in a VMEM scratch across the K sweep.  Tile dims
default to 128/256 — multiples of the 128-wide MXU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, xs_ref, ws_ref, o_ref, acc_ref, *, n_k: int):
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        x_ref[...], w_ref[...],
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32)

    @pl.when(k_idx == n_k - 1)
    def _epilogue():
        scale = xs_ref[...][:, None] * ws_ref[...][None, :]
        o_ref[...] = acc_ref[...].astype(jnp.float32) * scale


def _round_up(x: int, mult: int) -> int:
    return -(-x // mult) * mult


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def int8_matmul(x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array,
                w_scale: jax.Array, *, bm: int = 128, bn: int = 128,
                bk: int = 256, interpret: bool = False) -> jax.Array:
    """x_q: (M, K) int8; w_q: (K, N) int8; x_scale: (M,) f32 per-row;
    w_scale: (N,) f32 per-channel.  Returns (M, N) f32.

    Ragged M/K/N (not multiples of the block dims) are zero-padded up to
    the tile grid and the output sliced back — exact, because zero int8
    entries contribute nothing to the int32 dot and padded output
    rows/cols are dropped.  Tiles stay (8, 128)-aligned rather than
    shrinking to the ragged remainder (misaligned tiles stall the MXU).
    """
    m, k = x_q.shape
    k2, n = w_q.shape
    assert k == k2, (k, k2)
    # Clamp oversized blocks to the (aligned) problem dim, then pad every
    # dim up to its block multiple.
    bm = min(bm, _round_up(m, 8))
    bn = min(bn, _round_up(n, 128))
    bk = min(bk, _round_up(k, 128))
    mp, np_, kp = _round_up(m, bm), _round_up(n, bn), _round_up(k, bk)
    if (mp, np_, kp) != (m, n, k):
        x_q = jnp.pad(x_q, ((0, mp - m), (0, kp - k)))
        w_q = jnp.pad(w_q, ((0, kp - k), (0, np_ - n)))
        x_scale = jnp.pad(x_scale, (0, mp - m))
        w_scale = jnp.pad(w_scale, (0, np_ - n))
    n_k = kp // bk

    grid = (mp // bm, np_ // bn, n_k)
    out = pl.pallas_call(
        functools.partial(_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=interpret,
    )(x_q, w_q, x_scale, w_scale)
    return out[:m, :n] if (mp, np_) != (m, n) else out
