"""Pallas TPU kernels: flash-decoding and chunk-prefill attention over
the slot-addressed KV cache.

One-token decode attention for the serving tier: every generated token
streams the KV cache exactly once, in its stored precision.  Grid is
(slot, kv-head, kv-block) with the KV sweep innermost so the online-
softmax running state (max, sum, acc) lives in VMEM scratch across the
blocks of one (slot, kv-head) pair.

Three things distinguish this from the prefill flash kernel:

* **Grouped-query GQA in-kernel** — the q tile is the (G, D) group of
  query heads sharing one KV head, so KV is never repeated (repeating a
  slot cache costs G× its HBM bytes; see ``layers.decode_attention``'s
  history).
* **Per-slot KV-length bounding** — ``kv_len (B,)`` is each slot's
  high-water mark (entries at index >= kv_len are guaranteed invalid,
  position −1).  Blocks entirely past it are skipped: their compute is
  predicated off AND their index map is clamped to the last live block,
  so the pipeline elides the HBM→VMEM copy.  Capacity is sized for
  ``max_prompt + max_new_cap`` but typical requests fill a fraction of
  it; decode HBM traffic tracks actual occupancy, not capacity — and
  with pad-free chunked admission the fill is exactly the live tokens.
* **Fused Int8KV dequant** — int8 values and their per-(entry, head)
  f32 scales are read and dequantized inside the VMEM tile; decode never
  materializes a float copy of the cache.

Masking is identical to the jnp ref: stored position −1 is invalid,
``pos <= q_pos`` (causal), and ``pos > q_pos - window`` for sliding-
window layers.  A slot with no valid entries (kv_len == 0, or all
positions −1) produces zeros, matching ``ref.decode_attention_ref``.

``flash_chunk_prefill`` is the C-query sibling serving chunked pad-free
admission: the q tile carries the whole chunk's grouped query rows
(C × G), per-row query positions ride in a VMEM operand (causality
across the chunk is pure position masking — the chunk's KV is already
in the cache), and the kv_len bounding / in-tile Int8KV dequant are
shared with the decode kernel.

Both kernels additionally speak the **paged pool** layout
(docs/paged_kv.md): with a ``block_table`` (B, n_blocks) scalar-prefetch
operand, k/v become an (NB, BS, Hkv, D) pool of fixed-size blocks and
the grid's KV-block index resolves through the slot's table row inside
the index maps — the DMA stream touches exactly the slot's blocks, the
kv_len clamp/skip logic is unchanged, and ``kv_block_size`` (the tile
helper shared with serve/kvcache.py) guarantees pool block == kernel
block.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def kv_block_size(capacity: int, block_k: int = 128) -> int:
    """KV block granularity at a given per-slot capacity: the flash
    kernels' tile choice — min(block_k, capacity), halved until it
    divides capacity cleanly (floored at 8).  This is the single source
    of truth shared by the kernels, the serving engines' capacity
    rounding, and the paged ``BlockManager``'s physical block size (the
    paged pool's block == the kernel's KV grid block, so the block-table
    index map needs no sub-block arithmetic)."""
    bk = min(block_k, max(int(capacity), 1))
    while capacity % bk and bk > 8:
        bk //= 2
    return bk


def _kernel(qp_ref, kl_ref, *refs,
            scale: float, bk: int, n_k: int, window: int, int8: bool,
            paged: bool):
    if paged:
        _tbl_ref, *refs = refs          # consumed by the index maps only
    q_ref, k_ref, v_ref, pos_ref, *rest = refs
    if int8:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    bi = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kvl = kl_ref[bi]

    # Block liveness: the scheduler guarantees entries at index >= kv_len
    # are invalid, so blocks past the high-water mark contribute nothing.
    @pl.when(ki * bk < kvl)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (G, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bk, D)
        if int8:
            k = k * ks_ref[0].astype(jnp.float32)            # (bk, 1) scales
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        pos = pos_ref[...]                                   # (1, bk) int32
        qp = qp_ref[bi]
        idx = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        valid = (pos >= 0) & (pos <= qp) & (idx < kvl)
        if window > 0:
            valid &= pos > qp - window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        # explicit mask multiply: an all-invalid block has m_new == NEG_INF
        # and exp(s - m_new) == 1 there — the mask zeroes it so empty
        # slots finalize to exactly 0 instead of a garbage mean.
        p = jnp.exp(s - m_new[:, None]) * valid.astype(jnp.float32)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        m_ref[...] = m_new
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if int8:
            v = v * vs_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _pad_seq(x: Optional[jax.Array], pad: int, axis: int, value=0):
    if x is None or pad == 0:
        return x
    widths = [(0, 0)] * x.ndim
    widths[axis] = (0, pad)
    return jnp.pad(x, widths, constant_values=value)


@functools.partial(jax.jit,
                   static_argnames=("window", "block_k", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 q_pos: jax.Array, cache_pos: jax.Array, kv_len: jax.Array,
                 *, k_scale: Optional[jax.Array] = None,
                 v_scale: Optional[jax.Array] = None,
                 block_table: Optional[jax.Array] = None,
                 window: int = 0, block_k: int = 128,
                 interpret: bool = False) -> jax.Array:
    """q: (B, Hkv, G, D) grouped queries.

    Contiguous (slot-rectangle) layout — ``block_table is None``:
    k/v: (B, S, Hkv, D) float — or int8 with ``k_scale``/``v_scale``
    (B, S, Hkv) f32 per-(entry, head) scales.  q_pos: (B,) absolute
    query positions; cache_pos: (B, S) stored positions (−1 invalid);
    kv_len: (B,) per-slot high-water mark (use S for "scan everything").

    Paged layout — ``block_table`` (B, n_blocks) int32: k/v are a global
    *pool* (NB, BS, Hkv, D) of fixed-size KV blocks (scales (NB, BS,
    Hkv); cache_pos (NB, BS)); slot ``b``'s logical KV block ``j`` lives
    in physical block ``block_table[b, j]``.  The grid's KV-block index
    resolves through the table inside the index maps, so the pipeline
    DMAs exactly the slot's blocks — there is no per-slot capacity
    rectangle in HBM at all.  Entries of the table beyond the slot's
    live region must still hold a *valid* physical block id (0 is fine):
    the kv_len clamp re-maps dead grid steps onto the last live block
    and predicates their compute off, exactly as in the contiguous
    layout.  ``kv_len`` remains the *logical* per-slot fill.

    Returns (B, Hkv, G, D) in q.dtype.

    Callers should size S to a multiple of the KV block (the servers
    round capacity up) — ragged S first shrinks the block (halving down
    to 8) and only then pads, which costs a cache copy per call.  In the
    paged layout the kernel block IS the pool block (``kv_block_size``),
    so no shrink/pad path exists.
    """
    b, hkv, g, d = q.shape
    paged = block_table is not None
    if paged:
        # pool block == kernel KV block by construction (kv_block_size)
        bk = k.shape[1]
        n_k = block_table.shape[1]
        pad = 0
    else:
        s = k.shape[1]
        # prefer a block that divides S (halving down to 8) over padding —
        # padding copies the cache once per call
        bk = kv_block_size(s, block_k)
        pad = (-s) % bk
        if pad:
            k = _pad_seq(k, pad, 1)
            v = _pad_seq(v, pad, 1)
            k_scale = _pad_seq(k_scale, pad, 1)
            v_scale = _pad_seq(v_scale, pad, 1)
            cache_pos = _pad_seq(cache_pos, pad, 1, value=-1)
        n_k = (s + pad) // bk
    int8 = k_scale is not None

    def _clamp(bi, ki, kl):
        # Dead blocks re-map to the last live one: an unchanged block
        # index means the pipeline skips the HBM→VMEM copy entirely.
        last_live = jnp.maximum(pl.cdiv(kl[bi], bk) - 1, 0)
        return jnp.minimum(ki, last_live)

    if paged:
        def q_index(bi, hi, ki, qp, kl, tbl):
            return (bi, hi, 0, 0)

        def kv_index(bi, hi, ki, qp, kl, tbl):
            return (tbl[bi, _clamp(bi, ki, kl)], 0, hi, 0)

        def pos_index(bi, hi, ki, qp, kl, tbl):
            return (tbl[bi, _clamp(bi, ki, kl)], 0)

        def scale_index(bi, hi, ki, qp, kl, tbl):
            return (tbl[bi, _clamp(bi, ki, kl)], 0, hi)
    else:
        def q_index(bi, hi, ki, qp, kl):
            return (bi, hi, 0, 0)

        def kv_index(bi, hi, ki, qp, kl):
            return (bi, _clamp(bi, ki, kl), hi, 0)

        def pos_index(bi, hi, ki, qp, kl):
            return (bi, _clamp(bi, ki, kl))

        def scale_index(bi, hi, ki, qp, kl):
            return (bi, _clamp(bi, ki, kl), hi)

    in_specs = [
        pl.BlockSpec((1, 1, g, d), q_index),
        pl.BlockSpec((1, bk, 1, d), kv_index),
        pl.BlockSpec((1, bk, 1, d), kv_index),
        pl.BlockSpec((1, bk), pos_index),
    ]
    operands = [q, k, v, cache_pos]
    if int8:
        in_specs += [pl.BlockSpec((1, bk, 1), scale_index),
                     pl.BlockSpec((1, bk, 1), scale_index)]
        operands += [k_scale, v_scale]

    prefetch = [q_pos.astype(jnp.int32), kv_len.astype(jnp.int32)]
    if paged:
        prefetch.append(block_table.astype(jnp.int32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=(b, hkv, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d), q_index),
        scratch_shapes=[
            pltpu.VMEM((g,), jnp.float32),       # running max
            pltpu.VMEM((g,), jnp.float32),       # running sum
            pltpu.VMEM((g, d), jnp.float32),     # output accumulator
        ])
    kernel = functools.partial(
        _kernel, scale=d ** -0.5, bk=bk, n_k=n_k, window=window, int8=int8,
        paged=paged)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        interpret=interpret,
    )(*prefetch, *operands)


# ---------------------------------------------------------------------------
# Chunk-prefill attention (C queries per slot, cache-resident KV)
# ---------------------------------------------------------------------------
def _chunk_kernel(kl_ref, *refs,
                  scale: float, bk: int, n_k: int, window: int, int8: bool,
                  paged: bool):
    if paged:
        _tbl_ref, *refs = refs          # consumed by the index maps only
    qp_ref, q_ref, k_ref, v_ref, pos_ref, *rest = refs
    if int8:
        ks_ref, vs_ref, o_ref, m_ref, l_ref, acc_ref = rest
    else:
        o_ref, m_ref, l_ref, acc_ref = rest
    bi = pl.program_id(0)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    kvl = kl_ref[bi]

    @pl.when(ki * bk < kvl)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale          # (R, D)
        k = k_ref[0, :, 0, :].astype(jnp.float32)            # (bk, D)
        if int8:
            k = k * ks_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        pos = pos_ref[...]                                   # (1, bk) int32
        qp = qp_ref[0][:, None]                              # (R, 1) int32
        idx = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        # pad query rows (qp == −1) have no valid key: pos >= 0 and
        # pos <= −1 can't both hold, so they finalize to exact zeros.
        valid = (pos >= 0) & (pos <= qp) & (idx < kvl)
        if window > 0:
            valid &= pos > qp - window
        s = jnp.where(valid, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None]) * valid.astype(jnp.float32)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        m_ref[...] = m_new
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        if int8:
            v = v * vs_ref[0].astype(jnp.float32)
        pv = jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("window", "block_k", "interpret"))
def flash_chunk_prefill(q: jax.Array, k: jax.Array, v: jax.Array,
                        q_pos: jax.Array, cache_pos: jax.Array,
                        kv_len: jax.Array,
                        *, k_scale: Optional[jax.Array] = None,
                        v_scale: Optional[jax.Array] = None,
                        block_table: Optional[jax.Array] = None,
                        window: int = 0, block_k: int = 128,
                        interpret: bool = False) -> jax.Array:
    """q: (B, Hkv, R, D) grouped chunk queries — R = C·G rows ordered
    (query, group), i.e. row ``c*G + g``; q_pos: (B, R) per-row absolute
    query positions, already G-repeated (−1 marks a pad query row, which
    returns exact zeros).  k/v: (B, S, Hkv, D) float — or int8 with
    ``k_scale``/``v_scale`` (B, S, Hkv) f32 scales.  cache_pos: (B, S)
    stored positions (−1 invalid); kv_len: (B,) per-slot post-write fill
    bounding the KV sweep (use S for "scan everything").  Returns
    (B, Hkv, R, D) in q.dtype.

    ``block_table`` (B, n_blocks) int32 switches to the paged-pool
    layout exactly as in ``flash_decode``: k/v (NB, BS, Hkv, D), scales
    (NB, BS, Hkv), cache_pos (NB, BS), and the KV-block grid index
    resolves through the slot's table row inside the index maps.

    The chunk's own KV must already be resident in the cache (written at
    its rows, or concatenated for ring layouts): in-chunk causality is
    decided purely by ``pos <= q_pos``, identical to the decode kernel.
    """
    b, hkv, r, d = q.shape
    paged = block_table is not None
    if paged:
        bk = k.shape[1]
        n_k = block_table.shape[1]
    else:
        s = k.shape[1]
        bk = kv_block_size(s, block_k)
        pad = (-s) % bk
        if pad:
            k = _pad_seq(k, pad, 1)
            v = _pad_seq(v, pad, 1)
            k_scale = _pad_seq(k_scale, pad, 1)
            v_scale = _pad_seq(v_scale, pad, 1)
            cache_pos = _pad_seq(cache_pos, pad, 1, value=-1)
        n_k = (s + pad) // bk
    int8 = k_scale is not None

    def _clamp(bi, ki, kl):
        last_live = jnp.maximum(pl.cdiv(kl[bi], bk) - 1, 0)
        return jnp.minimum(ki, last_live)

    if paged:
        def q_index(bi, hi, ki, kl, tbl):
            return (bi, hi, 0, 0)

        def qp_index(bi, hi, ki, kl, tbl):
            return (bi, 0)

        def kv_index(bi, hi, ki, kl, tbl):
            return (tbl[bi, _clamp(bi, ki, kl)], 0, hi, 0)

        def pos_index(bi, hi, ki, kl, tbl):
            return (tbl[bi, _clamp(bi, ki, kl)], 0)

        def scale_index(bi, hi, ki, kl, tbl):
            return (tbl[bi, _clamp(bi, ki, kl)], 0, hi)
    else:
        def q_index(bi, hi, ki, kl):
            return (bi, hi, 0, 0)

        def qp_index(bi, hi, ki, kl):
            return (bi, 0)

        def kv_index(bi, hi, ki, kl):
            return (bi, _clamp(bi, ki, kl), hi, 0)

        def pos_index(bi, hi, ki, kl):
            return (bi, _clamp(bi, ki, kl))

        def scale_index(bi, hi, ki, kl):
            return (bi, _clamp(bi, ki, kl), hi)

    in_specs = [
        pl.BlockSpec((1, r), qp_index),
        pl.BlockSpec((1, 1, r, d), q_index),
        pl.BlockSpec((1, bk, 1, d), kv_index),
        pl.BlockSpec((1, bk, 1, d), kv_index),
        pl.BlockSpec((1, bk), pos_index),
    ]
    operands = [q_pos.astype(jnp.int32), q, k, v, cache_pos]
    if int8:
        in_specs += [pl.BlockSpec((1, bk, 1), scale_index),
                     pl.BlockSpec((1, bk, 1), scale_index)]
        operands += [k_scale, v_scale]

    prefetch = [kv_len.astype(jnp.int32)]
    if paged:
        prefetch.append(block_table.astype(jnp.int32))
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=len(prefetch),
        grid=(b, hkv, n_k),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, r, d), q_index),
        scratch_shapes=[
            pltpu.VMEM((r,), jnp.float32),       # running max
            pltpu.VMEM((r,), jnp.float32),       # running sum
            pltpu.VMEM((r, d), jnp.float32),     # output accumulator
        ])
    kernel = functools.partial(
        _chunk_kernel, scale=d ** -0.5, bk=bk, n_k=n_k, window=window,
        int8=int8, paged=paged)
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, hkv, r, d), q.dtype),
        interpret=interpret,
    )(*prefetch, *operands)
