"""Pallas TPU kernel: chunked selective scan (diagonal SSM / Mamba1).

Grid: (batch, channel blocks, chunks) with the chunk dim innermost; the
recurrent state h (block_d × N) persists in VMEM scratch across the
chunk sweep.  Within a chunk the recurrence runs as a fori_loop over
timesteps entirely in VMEM/VREGs — the HBM traffic is exactly one read
of (x, dt, B, C) and one write of y per element, which is what makes the
TPU port of this memory-bound GPU kernel worthwhile.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, o_ref, hout_ref, h_ref, *,
            chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    a = a_ref[...].astype(jnp.float32)                 # (bd, N)

    def step(t, h):
        xt = x_ref[0, t, :].astype(jnp.float32)        # (bd,)
        dtt = dt_ref[0, t, :].astype(jnp.float32)      # (bd,)
        bt = b_ref[0, t, :].astype(jnp.float32)        # (N,)
        ct = c_ref[0, t, :].astype(jnp.float32)        # (N,)
        decay = jnp.exp(dtt[:, None] * a)              # (bd, N)
        h = decay * h + (dtt * xt)[:, None] * bt[None, :]
        o_ref[0, t, :] = (h * ct[None, :]).sum(axis=1).astype(o_ref.dtype)
        return h

    h = jax.lax.fori_loop(0, chunk, step, h_ref[...])
    h_ref[...] = h

    @pl.when(ci == n_chunks - 1)
    def _emit_state():
        hout_ref[0] = h_ref[...]


@functools.partial(jax.jit, static_argnames=("block_d", "chunk", "interpret"))
def mamba_scan(x: jax.Array, dt: jax.Array, b_mat: jax.Array,
               c_mat: jax.Array, a: jax.Array, *, block_d: int = 512,
               chunk: int = 128, interpret: bool = False):
    """x/dt: (B, S, D); b_mat/c_mat: (B, S, N); a: (D, N).

    Returns (y (B, S, D) f32, h_final (B, D, N) f32)."""
    bsz, s, d = x.shape
    n = b_mat.shape[-1]
    block_d = min(block_d, d)
    chunk = min(chunk, s)
    assert d % block_d == 0 and s % chunk == 0, (d, block_d, s, chunk)
    n_chunks = s // chunk

    grid = (bsz, d // block_d, n_chunks)
    kernel = functools.partial(_kernel, chunk=chunk, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((1, chunk, block_d), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((1, chunk, n), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, di, ci: (b, ci, 0)),
            pl.BlockSpec((block_d, n), lambda b, di, ci: (di, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((1, block_d, n), lambda b, di, ci: (b, di, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, d), jnp.float32),
            jax.ShapeDtypeStruct((bsz, d, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((block_d, n), jnp.float32)],
        interpret=interpret,
    )(x, dt, b_mat, c_mat, a)
