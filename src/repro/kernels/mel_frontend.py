"""Pallas TPU kernel: mel frontend (window → DFT-as-matmul → power → mel).

Hardware adaptation of the paper's DSP stage (§4.2): on a Cortex-M the
MFE runs as a radix-2 FFT in CMSIS-DSP; a butterfly FFT is hostile to a
128×128 systolic array, but the (frames × DFT-matrix) product is exactly
an MXU matmul.  For KWS frame lengths (L ≤ 1024) the dense DFT is
compute-competitive and keeps the whole frontend in one fused kernel:
frames tile in VMEM, two matmuls (cos/sin), square-add, mel matmul, log.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(frames_ref, window_ref, cos_ref, sin_ref, mel_ref, o_ref, *,
            log_floor: float):
    xw = frames_ref[...].astype(jnp.float32) * window_ref[...][None, :]
    re = jax.lax.dot(xw, cos_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    im = jax.lax.dot(xw, sin_ref[...].astype(jnp.float32),
                     preferred_element_type=jnp.float32)
    power = re * re + im * im
    mel = jax.lax.dot(power, mel_ref[...].astype(jnp.float32),
                      preferred_element_type=jnp.float32)
    o_ref[...] = jnp.log(jnp.maximum(mel, log_floor))


@functools.partial(jax.jit, static_argnames=("block_f", "log_floor",
                                             "interpret"))
def mel_frontend(frames: jax.Array, window: jax.Array, dft_cos: jax.Array,
                 dft_sin: jax.Array, mel_fb: jax.Array, *,
                 block_f: int = 128, log_floor: float = 1e-6,
                 interpret: bool = False) -> jax.Array:
    """frames: (F, L); window: (L,); dft_cos/sin: (L, nbins);
    mel_fb: (nbins, n_mels).  Returns log-mel (F, n_mels) f32.

    Batch dims fold into F upstream (ops.py)."""
    f, l = frames.shape
    nbins = dft_cos.shape[1]
    n_mels = mel_fb.shape[1]
    block_f = min(block_f, f)
    assert f % block_f == 0, (f, block_f)

    return pl.pallas_call(
        functools.partial(_kernel, log_floor=log_floor),
        grid=(f // block_f,),
        in_specs=[
            pl.BlockSpec((block_f, l), lambda i: (i, 0)),
            pl.BlockSpec((l,), lambda i: (0,)),
            pl.BlockSpec((l, nbins), lambda i: (0, 0)),
            pl.BlockSpec((l, nbins), lambda i: (0, 0)),
            pl.BlockSpec((nbins, n_mels), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_f, n_mels), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((f, n_mels), jnp.float32),
        interpret=interpret,
    )(frames, window, dft_cos, dft_sin, mel_fb)
