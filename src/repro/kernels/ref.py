"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each function is the mathematical definition the kernel must reproduce;
tests sweep shapes/dtypes and assert allclose(kernel(interpret=True), ref).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# int8 matmul with per-channel dequant (paper C5: full int8 inference)
# ---------------------------------------------------------------------------
def int8_matmul_ref(x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array,
                    w_scale: jax.Array) -> jax.Array:
    """x_q: (M, K) int8; w_q: (K, N) int8; x_scale: (M,) or () f32;
    w_scale: (N,) f32 per-output-channel.  Returns f32 (M, N)."""
    acc = jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32))
    scale = jnp.atleast_1d(x_scale)[:, None] * w_scale[None, :]
    return acc.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# flash attention (causal, optional sliding window)
# ---------------------------------------------------------------------------
def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q/k/v: (B, S, H, D) same head count (GQA expansion happens outside).
    f32 math, output in q.dtype."""
    b, s, h, d = q.shape
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask = kp <= qp
    if window > 0:
        mask &= kp > qp - window
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# chunked selective scan (mamba1-style diagonal SSM)
# ---------------------------------------------------------------------------
def mamba_scan_ref(x: jax.Array, dt: jax.Array, b_mat: jax.Array,
                   c_mat: jax.Array, a: jax.Array,
                   h0: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """x/dt: (B, S, D); b_mat/c_mat: (B, S, N); a: (D, N) negative.

    h[t] = exp(dt[t] ⊙ a) * h[t-1] + (dt[t]*x[t]) ⊗ b[t];  y[t] = h[t]·c[t]
    Returns (y (B, S, D) f32, h_final (B, D, N) f32)."""
    bsz, s, d = x.shape
    n = b_mat.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b_mat.astype(jnp.float32)
    cf = c_mat.astype(jnp.float32)
    af = a.astype(jnp.float32)
    h = jnp.zeros((bsz, d, n), jnp.float32) if h0 is None else h0

    def step(h, inputs):
        xt, dtt, bt, ct = inputs
        decay = jnp.exp(dtt[:, :, None] * af)
        h = decay * h + (dtt * xt)[:, :, None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    h_final, ys = jax.lax.scan(
        step, h, (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
                  jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), h_final


# ---------------------------------------------------------------------------
# mel frontend (framing → window → DFT-as-matmul → power → mel → log)
# ---------------------------------------------------------------------------
def frame_signal(signal: jax.Array, frame_len: int, stride: int) -> jax.Array:
    """(B, T) -> (B, n_frames, frame_len)."""
    t = signal.shape[-1]
    n_frames = 1 + (t - frame_len) // stride
    idx = (np.arange(n_frames)[:, None] * stride
           + np.arange(frame_len)[None, :])
    return signal[..., idx]


def mel_frontend_ref(frames: jax.Array, window: jax.Array,
                     dft_cos: jax.Array, dft_sin: jax.Array,
                     mel_fb: jax.Array, log_floor: float = 1e-6
                     ) -> jax.Array:
    """frames: (B, F, L); window: (L,); dft_cos/sin: (L, nbins);
    mel_fb: (nbins, n_mels).  Returns log-mel (B, F, n_mels) f32.

    The DFT is two dense matmuls (MXU-native, vs butterfly FFT)."""
    xw = frames.astype(jnp.float32) * window.astype(jnp.float32)
    re = xw @ dft_cos.astype(jnp.float32)
    im = xw @ dft_sin.astype(jnp.float32)
    power = re * re + im * im
    mel = power @ mel_fb.astype(jnp.float32)
    return jnp.log(jnp.maximum(mel, log_floor))
