"""Pure-jnp oracles for every Pallas kernel (the correctness contracts).

Each function is the mathematical definition the kernel must reproduce;
tests sweep shapes/dtypes and assert allclose(kernel(interpret=True), ref).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# int8 matmul with per-channel dequant (paper C5: full int8 inference)
# ---------------------------------------------------------------------------
def int8_matmul_ref(x_q: jax.Array, w_q: jax.Array, x_scale: jax.Array,
                    w_scale: jax.Array) -> jax.Array:
    """x_q: (M, K) int8; w_q: (K, N) int8; x_scale: (M,) or () f32;
    w_scale: (N,) f32 per-output-channel.  Returns f32 (M, N)."""
    acc = jnp.matmul(x_q.astype(jnp.int32), w_q.astype(jnp.int32))
    scale = jnp.atleast_1d(x_scale)[:, None] * w_scale[None, :]
    return acc.astype(jnp.float32) * scale


# ---------------------------------------------------------------------------
# flash attention (causal, optional sliding window)
# ---------------------------------------------------------------------------
def flash_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        causal: bool = True, window: int = 0) -> jax.Array:
    """q/k/v: (B, S, H, D) same head count (GQA expansion happens outside).
    f32 math, output in q.dtype."""
    b, s, h, d = q.shape
    scores = jnp.einsum("bqhd,bkhd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * (d ** -0.5)
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(s)[None, :]
    mask = jnp.ones((s, s), bool)
    if causal:
        mask = kp <= qp
    if window > 0:
        mask &= kp > qp - window
    scores = jnp.where(mask, scores, -1e30)
    p = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# flash decoding (one query token against a slot-addressed KV cache)
# ---------------------------------------------------------------------------
def decode_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                         q_position: jax.Array, cache_positions: jax.Array,
                         *, window: int = 0,
                         kv_len: Optional[jax.Array] = None,
                         k_scale: Optional[jax.Array] = None,
                         v_scale: Optional[jax.Array] = None,
                         block_k: int = 256) -> jax.Array:
    """One-token decode against a KV cache — the jnp einsum oracle of
    ``flash_decode``.

    q: (B, 1, Hq, D); k/v: (B, Skv, Hkv, D) float — or int8 values with
    ``k_scale``/``v_scale`` (B, Skv, Hkv) f32 per-(entry, head) scales;
    q_position: (B,); cache_positions: (B, Skv) with −1 marking invalid
    entries; ``kv_len`` optionally bounds the per-slot valid region by
    index (entries at index >= kv_len are masked; a slot with kv_len 0 —
    or no valid positions at all — returns exactly zeros, matching the
    kernel).

    Uses the grouped-q einsum (NOT a repeated-KV expansion):
    materializing a repeated KV cache costs G× the cache bytes (measured
    +8 GiB/device on qwen2-72b decode).  Int8 caches are dequantized
    **per (block_k)-entry tile** inside a ``lax.scan`` — the ref twin of
    the kernel's in-VMEM dequant — so even the simulation never holds a
    float copy of the whole cache.  When the cache's seq dim is sharded
    over mesh axes ("flash decoding"), SPMD turns the max/sum reductions
    into the partial-softmax collectives.

    Decode is the C == 1 case of chunk-prefill attention, so this is a
    thin delegation to ``chunk_attention_ref`` — one oracle owns the
    masking/softmax contract (the equivalence is additionally pinned by
    ``tests/test_chunked_prefill.py::test_chunk_attention_c1_matches_decode``).
    """
    return chunk_attention_ref(
        q, k, v, q_position[:, None], cache_positions, window=window,
        kv_len=kv_len, k_scale=k_scale, v_scale=v_scale, block_k=block_k)


# ---------------------------------------------------------------------------
# chunk-prefill attention (C query tokens against a slot-addressed KV cache)
# ---------------------------------------------------------------------------
def chunk_attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                        q_positions: jax.Array, cache_positions: jax.Array,
                        *, window: int = 0,
                        kv_len: Optional[jax.Array] = None,
                        k_scale: Optional[jax.Array] = None,
                        v_scale: Optional[jax.Array] = None,
                        block_k: int = 256) -> jax.Array:
    """Chunked pad-free prefill attention — the jnp einsum oracle of
    ``flash_chunk_prefill`` and the C-query generalization of
    ``decode_attention_ref``.

    q: (B, C, Hq, D) chunk queries; k/v: (B, Skv, Hkv, D) float — or int8
    values with ``k_scale``/``v_scale`` (B, Skv, Hkv) f32 per-(entry,
    head) scales; q_positions: (B, C) absolute positions (−1 marks a pad
    query in a ragged final chunk — its row returns exactly zeros);
    cache_positions: (B, Skv) stored positions with −1 invalid; ``kv_len``
    optionally bounds the per-row live cache region by index (the serving
    tier passes the post-write fill ``p + C``).

    The caller writes the chunk's own KV into the cache (or concatenates
    it, for ring layouts) *before* calling, so in-chunk causality is pure
    position masking: key position <= query position.  Grouped-q einsum
    and per-tile int8 dequant follow ``decode_attention_ref``.
    """
    b, c, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = d ** -0.5
    qg = (q * scale).reshape(b, c, hkv, g, d)
    out_dtype = v.dtype if v_scale is None else q.dtype

    bk = min(block_k, skv)
    pad = (-skv) % bk
    if pad:
        widths4 = ((0, 0), (0, pad), (0, 0), (0, 0))
        k, v = jnp.pad(k, widths4), jnp.pad(v, widths4)
        cache_positions = jnp.pad(cache_positions, ((0, 0), (0, pad)),
                                  constant_values=-1)
        if k_scale is not None:
            k_scale = jnp.pad(k_scale, ((0, 0), (0, pad), (0, 0)))
            v_scale = jnp.pad(v_scale, ((0, 0), (0, pad), (0, 0)))
    sp = skv + pad
    n_b = sp // bk

    def tiles(x):
        return jnp.moveaxis(x.reshape(b, n_b, bk, *x.shape[2:]), 1, 0)

    # scores (B, C, Hkv, G, Skv) f32 — K dequantized per tile when int8
    if k_scale is None:
        s = jnp.einsum("bchgd,bkhd->bchgk", qg, k,
                       preferred_element_type=jnp.float32)
    else:
        def score_tile(_, inp):
            kq, ks = inp
            kf = (kq.astype(jnp.float32) * ks[..., None]).astype(q.dtype)
            return None, jnp.einsum("bchgd,bkhd->bchgk", qg, kf,
                                    preferred_element_type=jnp.float32)
        _, s_tiles = jax.lax.scan(score_tile, None,
                                  (tiles(k), tiles(k_scale)))
        s = jnp.moveaxis(s_tiles, 0, 4).reshape(b, c, hkv, g, sp)

    kp = cache_positions[:, None, :]                       # (B, 1, Skv)
    qp = q_positions[:, :, None]                           # (B, C, 1)
    valid = (kp >= 0) & (kp <= qp)
    if window > 0:
        valid &= kp > qp - window
    if kv_len is not None:
        idx = jnp.arange(sp, dtype=jnp.int32)[None, None, :]
        valid &= idx < kv_len[:, None, None].astype(jnp.int32)
    vmask = valid[:, :, None, None, :]                     # (B,C,1,1,Skv)
    s = jnp.where(vmask, s, NEG_INF)

    # masked softmax: a query row with no valid key (a pad query, or an
    # empty cache) produces exactly 0 instead of a garbage mean.
    m = jnp.max(s, axis=-1, keepdims=True)
    p = jnp.exp(s - m) * vmask
    l = jnp.sum(p, axis=-1, keepdims=True)
    p = p / jnp.where(l == 0.0, 1.0, l)

    if v_scale is None:
        o = jnp.einsum("bchgk,bkhd->bchgd", p.astype(v.dtype), v)
    else:
        def pv_tile(acc, inp):
            pt, vq, vs = inp
            vf = (vq.astype(jnp.float32) * vs[..., None]).astype(q.dtype)
            pv = jnp.einsum("bchgk,bkhd->bchgd", pt.astype(q.dtype), vf)
            return acc + pv.astype(jnp.float32), None
        p_tiles = jnp.moveaxis(p.reshape(b, c, hkv, g, n_b, bk), 4, 0)
        acc0 = jnp.zeros((b, c, hkv, g, d), jnp.float32)
        o, _ = jax.lax.scan(pv_tile, acc0,
                            (p_tiles, tiles(v), tiles(v_scale)))
    return o.reshape(b, c, hq, d).astype(out_dtype)


# ---------------------------------------------------------------------------
# paged KV pool (block-table indirection, serving tier)
# ---------------------------------------------------------------------------
def gather_kv_pages(pool: jax.Array, block_table: jax.Array) -> jax.Array:
    """Materialize a slot-contiguous view of a paged KV pool.

    pool: (NB, BS, ...) — NB physical blocks of BS entries each;
    block_table: (B, n) int32 — slot ``b``'s logical block ``j`` lives
    in physical block ``block_table[b, j]``.  Returns (B, n · BS, ...):
    logical entry ``i`` of slot ``b`` is ``pool[table[b, i // BS],
    i % BS]``.

    This is the *same* indirection the Pallas kernels' index maps
    perform one block at a time — the oracle gathers through the
    identical table, so kernel-vs-ref parity pins the paged addressing,
    not just the softmax math.  Entries past a slot's ``kv_len`` come
    from whatever block the table names there (0 by convention); they
    must be masked by the caller's ``kv_len`` bound exactly as in the
    kernel.
    """
    b, n = block_table.shape
    pages = pool[block_table]                    # (B, n, BS, ...)
    return pages.reshape((b, n * pool.shape[1]) + pool.shape[2:])


def paged_chunk_attention_ref(q: jax.Array, k_pool: jax.Array,
                              v_pool: jax.Array, q_positions: jax.Array,
                              pool_positions: jax.Array,
                              block_table: jax.Array,
                              kv_len: jax.Array, *, window: int = 0,
                              k_scale: Optional[jax.Array] = None,
                              v_scale: Optional[jax.Array] = None
                              ) -> jax.Array:
    """``chunk_attention_ref`` over a paged pool: gather each operand
    through the block table, then delegate.  ``kv_len`` is mandatory —
    in the paged layout it is the only thing standing between a slot
    and the stale/foreign entries of the blocks its table tail names."""
    k = gather_kv_pages(k_pool, block_table)
    v = gather_kv_pages(v_pool, block_table)
    cache_positions = gather_kv_pages(pool_positions, block_table)
    if k_scale is not None:
        k_scale = gather_kv_pages(k_scale, block_table)
        v_scale = gather_kv_pages(v_scale, block_table)
    return chunk_attention_ref(
        q, k, v, q_positions, cache_positions, window=window,
        kv_len=kv_len, k_scale=k_scale, v_scale=v_scale)


def paged_decode_attention_ref(q: jax.Array, k_pool: jax.Array,
                               v_pool: jax.Array, q_position: jax.Array,
                               pool_positions: jax.Array,
                               block_table: jax.Array,
                               kv_len: jax.Array, *, window: int = 0,
                               k_scale: Optional[jax.Array] = None,
                               v_scale: Optional[jax.Array] = None
                               ) -> jax.Array:
    """Decode (C == 1) case of ``paged_chunk_attention_ref``."""
    return paged_chunk_attention_ref(
        q, k_pool, v_pool, q_position[:, None], pool_positions,
        block_table, kv_len, window=window, k_scale=k_scale,
        v_scale=v_scale)


# ---------------------------------------------------------------------------
# chunked selective scan (mamba1-style diagonal SSM)
# ---------------------------------------------------------------------------
def mamba_scan_ref(x: jax.Array, dt: jax.Array, b_mat: jax.Array,
                   c_mat: jax.Array, a: jax.Array,
                   h0: Optional[jax.Array] = None
                   ) -> Tuple[jax.Array, jax.Array]:
    """x/dt: (B, S, D); b_mat/c_mat: (B, S, N); a: (D, N) negative.

    h[t] = exp(dt[t] ⊙ a) * h[t-1] + (dt[t]*x[t]) ⊗ b[t];  y[t] = h[t]·c[t]
    Returns (y (B, S, D) f32, h_final (B, D, N) f32)."""
    bsz, s, d = x.shape
    n = b_mat.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    bf = b_mat.astype(jnp.float32)
    cf = c_mat.astype(jnp.float32)
    af = a.astype(jnp.float32)
    h = jnp.zeros((bsz, d, n), jnp.float32) if h0 is None else h0

    def step(h, inputs):
        xt, dtt, bt, ct = inputs
        decay = jnp.exp(dtt[:, :, None] * af)
        h = decay * h + (dtt * xt)[:, :, None] * bt[:, None, :]
        y = jnp.einsum("bdn,bn->bd", h, ct)
        return h, y

    h_final, ys = jax.lax.scan(
        step, h, (jnp.moveaxis(xf, 1, 0), jnp.moveaxis(dtf, 1, 0),
                  jnp.moveaxis(bf, 1, 0), jnp.moveaxis(cf, 1, 0)))
    return jnp.moveaxis(ys, 0, 1), h_final


# ---------------------------------------------------------------------------
# mel frontend (framing → window → DFT-as-matmul → power → mel → log)
# ---------------------------------------------------------------------------
def frame_signal(signal: jax.Array, frame_len: int, stride: int) -> jax.Array:
    """(B, T) -> (B, n_frames, frame_len)."""
    t = signal.shape[-1]
    n_frames = 1 + (t - frame_len) // stride
    idx = (np.arange(n_frames)[:, None] * stride
           + np.arange(frame_len)[None, :])
    return signal[..., idx]


def mel_frontend_ref(frames: jax.Array, window: jax.Array,
                     dft_cos: jax.Array, dft_sin: jax.Array,
                     mel_fb: jax.Array, log_floor: float = 1e-6
                     ) -> jax.Array:
    """frames: (B, F, L); window: (L,); dft_cos/sin: (L, nbins);
    mel_fb: (nbins, n_mels).  Returns log-mel (B, F, n_mels) f32.

    The DFT is two dense matmuls (MXU-native, vs butterfly FFT)."""
    xw = frames.astype(jnp.float32) * window.astype(jnp.float32)
    re = xw @ dft_cos.astype(jnp.float32)
    im = xw @ dft_sin.astype(jnp.float32)
    power = re * re + im * im
    mel = power @ mel_fb.astype(jnp.float32)
    return jnp.log(jnp.maximum(mel, log_floor))
