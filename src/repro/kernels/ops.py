"""jit'd dispatch wrappers: Pallas kernel on TPU, jnp ref on CPU.

The model layers call these; on the CPU container every graph lowers via
the ref path (so dry-runs/pjit work), while on a real TPU backend the
Pallas kernels take over.  ``force`` pins a path for tests/benchmarks.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import flash_attention as fa
from repro.kernels import int8_matmul as im
from repro.kernels import mamba_scan as ms
from repro.kernels import mel_frontend as mf
from repro.kernels import ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def int8_matmul(x_q, w_q, x_scale, w_scale, *, force: Optional[str] = None):
    path = force or ("pallas" if _on_tpu() else "ref")
    if path == "pallas":
        return im.int8_matmul(x_q, w_q, x_scale, w_scale)
    if path == "interpret":
        return im.int8_matmul(x_q, w_q, x_scale, w_scale, interpret=True)
    return ref.int8_matmul_ref(x_q, w_q, x_scale, w_scale)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    force: Optional[str] = None):
    """q/k/v: (B, S, H, D) — GQA expansion done here; kernel takes (BH,S,D)."""
    path = force or ("pallas" if _on_tpu() else "ref")
    if path == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    b, s, h, d = q.shape
    if k.shape[2] != h:
        k = jnp.repeat(k, h // k.shape[2], axis=2)
        v = jnp.repeat(v, h // v.shape[2], axis=2)

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)

    out = fa.flash_attention(fold(q), fold(k), fold(v), causal=causal,
                             window=window,
                             interpret=(path == "interpret"))
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)


def mamba_scan(x, dt, b_mat, c_mat, a, *, force: Optional[str] = None
               ) -> Tuple[jax.Array, jax.Array]:
    path = force or ("pallas" if _on_tpu() else "ref")
    if path == "pallas":
        return ms.mamba_scan(x, dt, b_mat, c_mat, a)
    if path == "interpret":
        return ms.mamba_scan(x, dt, b_mat, c_mat, a, interpret=True)
    return ref.mamba_scan_ref(x, dt, b_mat, c_mat, a)


def mel_frontend(frames, window, dft_cos, dft_sin, mel_fb, *,
                 force: Optional[str] = None):
    """frames: (..., F, L) — leading dims folded into the grid."""
    path = force or ("pallas" if _on_tpu() else "ref")
    if path == "ref":
        return ref.mel_frontend_ref(frames, window, dft_cos, dft_sin, mel_fb)
    lead = frames.shape[:-2]
    f, l = frames.shape[-2:]
    flat = frames.reshape((-1, l)) if lead else frames
    # fold leading dims into the frame dim
    flat = frames.reshape((-1, l))
    out = mf.mel_frontend(flat, window, dft_cos, dft_sin, mel_fb,
                          interpret=(path == "interpret"))
    return out.reshape(*lead, f, mel_fb.shape[1]) if lead else out
