"""jit'd dispatch wrappers: Pallas kernel on TPU, jnp ref on CPU.

The model layers call these; on the CPU container every graph lowers via
the ref path (so dry-runs/pjit work), while on a real TPU backend the
Pallas kernels take over.  ``force`` pins a path for one call; the
``repro.flags`` level ``kernel_path`` (seeded from $REPRO_KERNEL_PATH)
pins every dispatch suite-wide, so CI can run the whole test matrix
through Pallas interpret mode without touching call sites.

``quant_matmul`` is the precision-aware matmul every dense/projection op
in ``models/`` routes through: plain float arrays take the untouched
``x @ w`` path, ``QTensor`` weights take the dynamic-activation int8
path (or its fake-quant float simulation, per ``PrecisionPolicy``).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro import flags
from repro.core.quantize import Int8KV, PrecisionPolicy, QTensor, quant_dynamic
from repro.kernels import flash_attention as fa
from repro.kernels import flash_decode as fd
from repro.kernels import int8_matmul as im
from repro.kernels import mamba_scan as ms
from repro.kernels import mel_frontend as mf
from repro.kernels import ref


def _on_tpu() -> bool:
    try:
        return jax.default_backend() == "tpu"
    except Exception:
        return False


def resolve_path(force: Optional[str] = None) -> str:
    """Backend for one kernel dispatch: per-call force > flags pin >
    default probe (pallas on TPU, ref elsewhere)."""
    return (force or flags.get("kernel_path")
            or ("pallas" if _on_tpu() else "ref"))


def int8_matmul(x_q, w_q, x_scale, w_scale, *, force: Optional[str] = None):
    path = resolve_path(force)
    if path == "pallas":
        return im.int8_matmul(x_q, w_q, x_scale, w_scale)
    if path == "interpret":
        return im.int8_matmul(x_q, w_q, x_scale, w_scale, interpret=True)
    return ref.int8_matmul_ref(x_q, w_q, x_scale, w_scale)


def quant_matmul(x: jax.Array, w, *,
                 policy: Optional[PrecisionPolicy] = None,
                 force: Optional[str] = None) -> jax.Array:
    """Precision-aware matmul: ``x (..., K) @ w (K, N)``.

    ``w`` is either a raw float array — the float path, identical to the
    pre-refactor ``x @ w.astype(x.dtype)`` — or a ``QTensor``: the input
    rows are quantized dynamically (or against the QTensor's calibrated
    amax), the int8×int8 kernel runs with dequant fused in its epilogue,
    and the f32 result is cast back to the activation dtype.  With
    ``policy.compute == "fake_quant"`` the same quantization decisions
    run in float: the *integer-valued* f32 matmul with scales applied
    once afterward — the same accumulate-then-scale order as the int8
    kernel, so the simulation is bit-identical to the native path while
    every partial dot product stays inside f32's exact-integer range
    (|sum| < 2^24, guaranteed at worst-case int8 magnitudes for K ≤ 1040
    and true in practice far beyond).  That is the reference the int8
    serving path is tested token-exact against.
    """
    if not isinstance(w, QTensor):
        return x @ w.astype(x.dtype)
    policy = policy or PrecisionPolicy(weights="int8")
    lead, kdim = x.shape[:-1], x.shape[-1]
    x2 = x.reshape(-1, kdim)
    amax = w.amax if policy.activations == "calibrated" else None
    xq, xs = quant_dynamic(x2, amax)
    if policy.compute == "fake_quant":
        acc = xq.astype(jnp.float32) @ w.q.astype(jnp.float32)
        out = acc * (xs[:, None] * w.scale[..., None, :])
    else:
        out = int8_matmul(xq, w.q, xs, w.scale, force=force)
    return out.reshape(*lead, w.q.shape[-1]).astype(x.dtype)


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    force: Optional[str] = None):
    """q/k/v: (B, S, H, D) — GQA expansion done here; kernel takes (BH,S,D)."""
    path = resolve_path(force)
    if path == "ref":
        return ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    b, s, h, d = q.shape
    if k.shape[2] != h:
        k = jnp.repeat(k, h // k.shape[2], axis=2)
        v = jnp.repeat(v, h // v.shape[2], axis=2)

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)

    out = fa.flash_attention(fold(q), fold(k), fold(v), causal=causal,
                             window=window,
                             interpret=(path == "interpret"))
    return out.reshape(b, h, s, d).transpose(0, 2, 1, 3)


def decode_attention(q, k_cache, v_cache, q_position, cache_positions, *,
                     window: int = 0,
                     kv_len: Optional[jax.Array] = None,
                     block_table: Optional[jax.Array] = None,
                     force: Optional[str] = None) -> jax.Array:
    """One-token decode attention against a slot-addressed KV cache.

    q: (B, 1, Hq, D); ``k_cache``/``v_cache``: (B, Skv, Hkv, D) float
    arrays or ``Int8KV`` pairs; q_position: (B,); cache_positions:
    (B, Skv) stored positions, −1 marking invalid entries.

    ``kv_len`` (B,) is the serving tier's per-slot high-water mark: the
    caller guarantees every entry at index >= kv_len[b] is invalid, so
    the kernel skips those blocks outright (capacity is sized for the
    worst case; typical slots fill a fraction of it).  ``None`` means no
    bound (scan the whole cache; masking alone decides validity).

    ``block_table`` (B, n_blocks) int32 switches to the **paged pool**
    layout (docs/paged_kv.md): caches are (NB, BS, Hkv, D) pools of
    fixed-size blocks, ``cache_positions`` is (NB, BS), and logical KV
    block ``j`` of slot ``b`` resolves to physical block
    ``block_table[b, j]`` — inside the Pallas index maps on the kernel
    paths, by an explicit gather through the same table in the ref
    oracle.  ``kv_len`` is then mandatory (it is what fences a slot off
    from the stale blocks its table tail names).

    Int8 caches are dequantized per tile — inside the Pallas VMEM tile
    on the kernel paths, per ``lax.scan`` block in the ref simulation —
    so decode never materializes a float copy of the cache.
    """
    path = resolve_path(force)
    if isinstance(k_cache, Int8KV):
        k, k_scale = k_cache.q, k_cache.scale
        v, v_scale = v_cache.q, v_cache.scale
    else:
        k, v, k_scale, v_scale = k_cache, v_cache, None, None
    if block_table is not None and kv_len is None:
        raise ValueError("paged decode_attention requires kv_len")
    if path == "ref":
        if block_table is not None:
            return ref.paged_decode_attention_ref(
                q, k, v, q_position, cache_positions, block_table,
                kv_len, window=window, k_scale=k_scale, v_scale=v_scale)
        return ref.decode_attention_ref(
            q, k, v, q_position, cache_positions, window=window,
            kv_len=kv_len, k_scale=k_scale, v_scale=v_scale)
    b, _, hq, d = q.shape
    hkv = k.shape[2]
    if kv_len is None:
        kv_len = jnp.full((b,), k.shape[1], jnp.int32)
    out = fd.flash_decode(
        q.reshape(b, hkv, hq // hkv, d), k, v,
        q_position.astype(jnp.int32), cache_positions, kv_len,
        k_scale=k_scale, v_scale=v_scale, block_table=block_table,
        window=window, interpret=(path == "interpret"))
    return out.reshape(b, 1, hq, d)


def chunk_attention(q, k_cache, v_cache, q_positions, cache_positions, *,
                    window: int = 0,
                    kv_len: Optional[jax.Array] = None,
                    block_table: Optional[jax.Array] = None,
                    force: Optional[str] = None) -> jax.Array:
    """Chunk-prefill attention: C query tokens per slot against the
    slot-addressed KV cache (the admission path of chunked pad-free
    prefill; ``decode_attention`` is the C == 1 case).

    q: (B, C, Hq, D); ``k_cache``/``v_cache``: (B, Skv, Hkv, D) float
    arrays or ``Int8KV`` pairs; q_positions: (B, C) absolute positions
    (−1 marks pad queries in a ragged final chunk — their outputs are
    exact zeros, discarded by the caller); cache_positions: (B, Skv).

    The chunk's own KV must already be resident (written into the cache
    rows, or concatenated for ring layouts) — in-chunk causality is pure
    position masking.  ``kv_len`` (B,) is the post-write fill ``p + C``:
    blocks past it are skipped by the kernel exactly as in decode.

    ``block_table`` (B, n_blocks) selects the paged-pool layout exactly
    as in ``decode_attention`` (pool caches, table-resolved index maps /
    ref gather, mandatory ``kv_len``).
    """
    path = resolve_path(force)
    if isinstance(k_cache, Int8KV):
        k, k_scale = k_cache.q, k_cache.scale
        v, v_scale = v_cache.q, v_cache.scale
    else:
        k, v, k_scale, v_scale = k_cache, v_cache, None, None
    if block_table is not None and kv_len is None:
        raise ValueError("paged chunk_attention requires kv_len")
    if path == "ref":
        if block_table is not None:
            return ref.paged_chunk_attention_ref(
                q, k, v, q_positions, cache_positions, block_table,
                kv_len, window=window, k_scale=k_scale, v_scale=v_scale)
        return ref.chunk_attention_ref(
            q, k, v, q_positions, cache_positions, window=window,
            kv_len=kv_len, k_scale=k_scale, v_scale=v_scale)
    b, c, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    if kv_len is None:
        kv_len = jnp.full((b,), k.shape[1], jnp.int32)
    # grouped rows ordered (query, group): row c*G + g shares KV head h
    qg = q.reshape(b, c, hkv, g, d).transpose(0, 2, 1, 3, 4) \
        .reshape(b, hkv, c * g, d)
    qp_rows = jnp.broadcast_to(q_positions[:, :, None],
                               (b, c, g)).reshape(b, c * g)
    out = fd.flash_chunk_prefill(
        qg, k, v, qp_rows.astype(jnp.int32), cache_positions, kv_len,
        k_scale=k_scale, v_scale=v_scale, block_table=block_table,
        window=window, interpret=(path == "interpret"))
    return out.reshape(b, hkv, c, g, d).transpose(0, 2, 1, 3, 4) \
        .reshape(b, c, hq, d)


def mamba_scan(x, dt, b_mat, c_mat, a, *, force: Optional[str] = None
               ) -> Tuple[jax.Array, jax.Array]:
    path = resolve_path(force)
    if path == "pallas":
        return ms.mamba_scan(x, dt, b_mat, c_mat, a)
    if path == "interpret":
        return ms.mamba_scan(x, dt, b_mat, c_mat, a, interpret=True)
    return ref.mamba_scan_ref(x, dt, b_mat, c_mat, a)


def mel_frontend(frames, window, dft_cos, dft_sin, mel_fb, *,
                 force: Optional[str] = None):
    """frames: (..., F, L) — leading dims folded into the grid."""
    path = resolve_path(force)
    if path == "ref":
        return ref.mel_frontend_ref(frames, window, dft_cos, dft_sin, mel_fb)
    lead = frames.shape[:-2]
    f, l = frames.shape[-2:]
    # fold leading dims into the frame dim
    flat = frames.reshape((-1, l))
    out = mf.mel_frontend(flat, window, dft_cos, dft_sin, mel_fb,
                          interpret=(path == "interpret"))
    return out.reshape(*lead, f, mel_fb.shape[1]) if lead else out
