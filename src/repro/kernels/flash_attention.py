"""Pallas TPU kernel: causal flash attention with online softmax.

VMEM tiling: (block_q × D) query tiles stream against (block_k × D)
KV tiles; running (max, sum, acc) live in VMEM scratch across the KV
sweep.  Causal block skipping: KV tiles strictly above the diagonal are
not computed (the 2× triangle saving the jnp ref path forgoes).
Sliding-window masking composes with the same skip logic.

Layout: q/k/v are (B*H, S, D) — batch and heads fold into the grid's
leading dim so one kernel serves any GQA expansion done by the caller.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
            scale: float, block_q: int, block_k: int, n_k: int,
            causal: bool, window: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    # tile-level liveness: causal tiles strictly above the diagonal and
    # window tiles entirely past the window are skipped (traced predicate)
    live = jnp.bool_(True)
    if causal:
        live &= k_start <= q_start + block_q - 1
    if window > 0:
        live &= k_start + block_k - 1 > q_start - window

    @pl.when(live)
    def _compute():
        q = q_ref[0].astype(jnp.float32) * scale
        k = k_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        qp = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 0)
        kp = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), bool)
        if causal:
            mask = kp <= qp
        if window > 0:
            mask &= kp > qp - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=1)
        m_ref[...] = m_new
        pv = jax.lax.dot_general(p, v_ref[0].astype(jnp.float32),
                                 (((1,), (0,)), ((), ())),
                                 preferred_element_type=jnp.float32)
        acc_ref[...] = acc_ref[...] * alpha[:, None] + pv

    @pl.when(ki == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int = 0,
                    block_q: int = 256, block_k: int = 256,
                    interpret: bool = False) -> jax.Array:
    """q/k/v: (BH, S, D) with identical head counts (GQA pre-expanded).
    Returns (BH, S, D) in q.dtype."""
    bh, s, d = q.shape
    block_q = min(block_q, s)
    block_k = min(block_k, s)
    assert s % block_q == 0 and s % block_k == 0, (s, block_q, block_k)
    n_q = s // block_q
    n_k = s // block_k
    scale = d ** -0.5

    grid = (bh, n_q, n_k)
    kernel = functools.partial(
        _kernel, scale=scale, block_q=block_q, block_k=block_k, n_k=n_k,
        causal=causal, window=window)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_k, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),      # running max
            pltpu.VMEM((block_q,), jnp.float32),      # running sum
            pltpu.VMEM((block_q, d), jnp.float32),    # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
