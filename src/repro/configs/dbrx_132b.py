"""dbrx-132b — MoE 16 experts top-4, fine-grained  [hf:databricks/dbrx-base; unverified]."""
from repro.core.arch import ArchConfig

FULL = ArchConfig(
    name="dbrx-132b", family="moe",
    n_layers=40, d_model=6144, n_heads=48, n_kv_heads=8,
    d_ff=10752, vocab_size=100352, rope_theta=5e5,
    n_experts=16, experts_per_tok=4,
)

SMOKE = ArchConfig(
    name="dbrx-132b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab_size=320, vocab_pad_multiple=64,
    n_experts=4, experts_per_tok=2,
)
