"""qwen2-vl-72b — VLM backbone: M-RoPE, dynamic resolution
[arXiv:2409.12191; hf].

The vision frontend is a STUB: ``input_specs()`` provides precomputed
patch embeddings plus 3-stream (t, h, w) M-RoPE position ids.  head_dim
128 → sections (16, 24, 24) rotary split per the paper.
"""
from repro.core.arch import ArchConfig

FULL = ArchConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv_heads=8,
    d_ff=29568, vocab_size=152064, rope_theta=1e6,
    rope_variant="mrope", mrope_sections=(16, 24, 24),
    frontend="vision",
)

SMOKE = ArchConfig(
    name="qwen2-vl-72b-smoke", family="vlm",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=320, vocab_pad_multiple=64, head_dim=16,
    rope_variant="mrope", mrope_sections=(2, 3, 3),
    frontend="vision",
)
