"""llama3.2-3b — dense, GQA, small llama3  [hf:meta-llama/Llama-3.2-1B; unverified]."""
from repro.core.arch import ArchConfig

FULL = ArchConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv_heads=8,
    d_ff=8192, vocab_size=128256, rope_theta=5e5,
    tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="llama3.2-3b-smoke", family="dense",
    n_layers=2, d_model=96, n_heads=6, n_kv_heads=2,
    d_ff=192, vocab_size=320, vocab_pad_multiple=64,
    tie_embeddings=True,
)
