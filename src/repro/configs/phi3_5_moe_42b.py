"""phi3.5-moe-42b-a6.6b — MoE 16 experts top-2  [hf:microsoft/Phi-3.5-MoE-instruct; hf]."""
from repro.core.arch import ArchConfig

FULL = ArchConfig(
    name="phi3.5-moe-42b-a6.6b", family="moe",
    n_layers=32, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=6400, vocab_size=32064, rope_theta=1e4,
    n_experts=16, experts_per_tok=2,
)

SMOKE = ArchConfig(
    name="phi3.5-moe-42b-smoke", family="moe",
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=96, vocab_size=320, vocab_pad_multiple=64,
    n_experts=4, experts_per_tok=2,
)
