"""zamba2-2.7b — hybrid: Mamba2 backbone + shared attention block every 6
layers  [arXiv:2411.15242; hf].

The shared transformer block (attention + MLP, weights SHARED across all
applications) runs after every ``attn_every`` Mamba2 layers; 54 layers →
9 scanned groups of 6.  kv=32 refers to the shared block's MHA.
"""
from repro.core.arch import ArchConfig

FULL = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000, rope_theta=1e4,
    ssm_state=64, ssm_variant="mamba2", ssm_expand=2,
    attn_every=6, tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="zamba2-2.7b-smoke", family="hybrid",
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=320, vocab_pad_multiple=64,
    ssm_state=8, ssm_variant="mamba2", ssm_expand=2, ssm_heads=4,
    attn_every=3, tie_embeddings=True,
)
