"""Architecture config registry: one module per assigned architecture.

``get(arch_id)`` returns the FULL config; ``get_smoke(arch_id)`` a reduced
config of the same structural family for CPU smoke tests.
"""
from __future__ import annotations

import importlib
from typing import Dict, List

from repro.core.arch import ArchConfig

ARCH_IDS: List[str] = [
    "internlm2_1_8b",
    "granite_3_8b",
    "gemma3_4b",
    "llama3_2_3b",
    "seamless_m4t_large_v2",
    "dbrx_132b",
    "phi3_5_moe_42b",
    "zamba2_2_7b",
    "falcon_mamba_7b",
    "qwen2_vl_72b",
]

# canonical dashed ids (CLI --arch) -> module names
ALIASES: Dict[str, str] = {
    "internlm2-1.8b": "internlm2_1_8b",
    "granite-3-8b": "granite_3_8b",
    "gemma3-4b": "gemma3_4b",
    "llama3.2-3b": "llama3_2_3b",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "dbrx-132b": "dbrx_132b",
    "phi3.5-moe-42b-a6.6b": "phi3_5_moe_42b",
    "zamba2-2.7b": "zamba2_2_7b",
    "falcon-mamba-7b": "falcon_mamba_7b",
    "qwen2-vl-72b": "qwen2_vl_72b",
}


def _module(arch_id: str):
    mod_name = ALIASES.get(arch_id, arch_id.replace("-", "_").replace(".", "_"))
    return importlib.import_module(f"repro.configs.{mod_name}")


def get(arch_id: str) -> ArchConfig:
    return _module(arch_id).FULL


def get_smoke(arch_id: str) -> ArchConfig:
    return _module(arch_id).SMOKE


def all_archs() -> List[str]:
    return list(ARCH_IDS)
