"""gemma3-4b — dense, 5:1 local:global attention, 128k context
[hf:google/gemma-3-1b-pt; unverified]."""
from repro.core.arch import ArchConfig

FULL = ArchConfig(
    name="gemma3-4b", family="dense",
    n_layers=34, d_model=2560, n_heads=8, n_kv_heads=4,
    d_ff=10240, vocab_size=262144, head_dim=256,
    sliding_window=1024, local_global_ratio=5,
    rope_theta=1e6, tie_embeddings=True,
)

SMOKE = ArchConfig(
    name="gemma3-4b-smoke", family="dense",
    n_layers=7, d_model=64, n_heads=4, n_kv_heads=2,
    d_ff=128, vocab_size=320, vocab_pad_multiple=64, head_dim=16,
    sliding_window=8, local_global_ratio=5, tie_embeddings=True,
)
