"""falcon-mamba-7b — attention-free Mamba1  [arXiv:2410.05355; unverified]."""
from repro.core.arch import ArchConfig

FULL = ArchConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=65024,
    ssm_state=16, ssm_variant="mamba1", ssm_expand=2,
)

SMOKE = ArchConfig(
    name="falcon-mamba-7b-smoke", family="ssm",
    n_layers=2, d_model=64, n_heads=0, n_kv_heads=0,
    d_ff=0, vocab_size=320, vocab_pad_multiple=64,
    ssm_state=8, ssm_variant="mamba1", ssm_expand=2,
)
