"""seamless-m4t-large-v2 — audio enc-dec, multimodal  [arXiv:2308.11596; hf].

24L is interpreted as 24 encoder + 24 decoder layers (the HF config's
speech-encoder/text-decoder depths).  The audio frontend is a STUB:
``input_specs()`` provides precomputed frame embeddings; enc_seq = seq/4
models the conv subsampling stage (DESIGN.md §4).
"""
from repro.core.arch import ArchConfig

FULL = ArchConfig(
    name="seamless-m4t-large-v2", family="audio",
    n_layers=24, n_enc_layers=24, is_encdec=True,
    d_model=1024, n_heads=16, n_kv_heads=16,
    d_ff=8192, vocab_size=256206, rope_theta=1e4,
    frontend="audio", enc_seq_divisor=4,
)

SMOKE = ArchConfig(
    name="seamless-m4t-large-v2-smoke", family="audio",
    n_layers=2, n_enc_layers=2, is_encdec=True,
    d_model=64, n_heads=4, n_kv_heads=4,
    d_ff=128, vocab_size=320, vocab_pad_multiple=64,
    frontend="audio", enc_seq_divisor=4,
)
