"""Mel filterbank + DFT-matrix construction (host-side, numpy).

The DFT is expressed as two dense matrices so the frontend is one chain
of MXU matmuls (DESIGN.md hardware-adaptation note); matches
librosa/CMSIS-DSP mel conventions closely enough for the paper's KWS
pipeline.
"""
from __future__ import annotations

import numpy as np


def hz_to_mel(f):
    return 2595.0 * np.log10(1.0 + np.asarray(f) / 700.0)


def mel_to_hz(m):
    return 700.0 * (10.0 ** (np.asarray(m) / 2595.0) - 1.0)


def mel_filterbank(n_bins: int, n_mels: int, sample_rate: int,
                   fmin: float = 20.0, fmax: float | None = None
                   ) -> np.ndarray:
    """(n_bins, n_mels) triangular filters; n_bins = n_fft//2 + 1."""
    fmax = fmax or sample_rate / 2
    mel_pts = np.linspace(hz_to_mel(fmin), hz_to_mel(fmax), n_mels + 2)
    hz_pts = mel_to_hz(mel_pts)
    n_fft = (n_bins - 1) * 2
    bins = np.floor((n_fft + 1) * hz_pts / sample_rate).astype(int)
    fb = np.zeros((n_bins, n_mels), np.float32)
    for m in range(n_mels):
        lo, ctr, hi = bins[m], bins[m + 1], bins[m + 2]
        for b in range(lo, min(ctr, n_bins)):
            if ctr > lo:
                fb[b, m] = (b - lo) / (ctr - lo)
        for b in range(ctr, min(hi, n_bins)):
            if hi > ctr:
                fb[b, m] = (hi - b) / (hi - ctr)
    return fb


def dft_matrices(frame_len: int, n_fft: int | None = None
                 ) -> tuple[np.ndarray, np.ndarray]:
    """Real-DFT as two dense matrices: (L, n_bins) cos and -sin."""
    n_fft = n_fft or frame_len
    n_bins = n_fft // 2 + 1
    t = np.arange(frame_len)[:, None]
    k = np.arange(n_bins)[None, :]
    ang = 2.0 * np.pi * t * k / n_fft
    return (np.cos(ang).astype(np.float32),
            (-np.sin(ang)).astype(np.float32))


def dct_matrix(n_mels: int, n_coeffs: int) -> np.ndarray:
    """Type-II orthonormal DCT (n_mels, n_coeffs) — MFCC from log-mel."""
    n = np.arange(n_mels)[:, None]
    k = np.arange(n_coeffs)[None, :]
    d = np.cos(np.pi * (n + 0.5) * k / n_mels)
    d *= np.sqrt(2.0 / n_mels)
    d[:, 0] /= np.sqrt(2.0)
    return d.astype(np.float32)
