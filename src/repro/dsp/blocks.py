"""DSP preprocessing blocks (paper §4.2): the continuum of feature
extractors the Impulse pipeline composes with model blocks.

Each block is a pure callable with declared hyperparameters and a
``feature_shape`` the tuner uses when sizing downstream model blocks.
The heavy path (framing → window → DFT → mel) dispatches through
``kernels/ops.mel_frontend`` (Pallas on TPU, jnp ref elsewhere).
"""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.dsp import filterbank as fb
from repro.kernels import ops as kops


def frame_signal(signal: jax.Array, frame_len: int, stride: int) -> jax.Array:
    t = signal.shape[-1]
    n_frames = 1 + (t - frame_len) // stride
    idx = (np.arange(n_frames)[:, None] * stride
           + np.arange(frame_len)[None, :])
    return signal[..., idx]


@dataclasses.dataclass(frozen=True)
class MFEBlock:
    """Mel-filterbank energies.  Hyperparameters mirror the paper's
    Table 3 notation: MFE(frame_s, stride_s, n_mels)."""
    sample_rate: int = 16_000
    frame_s: float = 0.02
    stride_s: float = 0.01
    n_mels: int = 40
    n_fft: int = 512
    name: str = "mfe"

    @property
    def frame_len(self) -> int:
        return int(self.sample_rate * self.frame_s)

    @property
    def stride(self) -> int:
        return int(self.sample_rate * self.stride_s)

    def feature_shape(self, n_samples: int) -> Tuple[int, int]:
        n_frames = 1 + (n_samples - self.frame_len) // self.stride
        return (n_frames, self.n_mels)

    def _tables(self):
        n_bins = self.n_fft // 2 + 1
        window = jnp.asarray(np.hanning(self.frame_len), jnp.float32)
        cos, sin = fb.dft_matrices(self.frame_len, self.n_fft)
        mel = fb.mel_filterbank(n_bins, self.n_mels, self.sample_rate)
        return window, jnp.asarray(cos), jnp.asarray(sin), jnp.asarray(mel)

    def __call__(self, signal: jax.Array) -> jax.Array:
        """(B, T) audio -> (B, n_frames, n_mels) log-mel."""
        frames = frame_signal(signal, self.frame_len, self.stride)
        window, cos, sin, mel = self._tables()
        return kops.mel_frontend(frames, window, cos, sin, mel)

    def hyperparams(self):
        return {"frame_s": self.frame_s, "stride_s": self.stride_s,
                "n_mels": self.n_mels}


@dataclasses.dataclass(frozen=True)
class MFCCBlock:
    """MFCCs = DCT-II of the log-mel energies."""
    sample_rate: int = 16_000
    frame_s: float = 0.02
    stride_s: float = 0.01
    n_mels: int = 40
    n_coeffs: int = 13
    n_fft: int = 512
    name: str = "mfcc"

    @property
    def _mfe(self) -> MFEBlock:
        return MFEBlock(self.sample_rate, self.frame_s, self.stride_s,
                        self.n_mels, self.n_fft)

    def feature_shape(self, n_samples: int) -> Tuple[int, int]:
        return (self._mfe.feature_shape(n_samples)[0], self.n_coeffs)

    def __call__(self, signal: jax.Array) -> jax.Array:
        logmel = self._mfe(signal)
        dct = jnp.asarray(fb.dct_matrix(self.n_mels, self.n_coeffs))
        return logmel @ dct

    def hyperparams(self):
        return {"frame_s": self.frame_s, "stride_s": self.stride_s,
                "n_mels": self.n_mels, "n_coeffs": self.n_coeffs}


@dataclasses.dataclass(frozen=True)
class SpectrogramBlock:
    sample_rate: int = 16_000
    frame_s: float = 0.02
    stride_s: float = 0.01
    n_fft: int = 256
    name: str = "spectrogram"

    def feature_shape(self, n_samples: int) -> Tuple[int, int]:
        frame_len = int(self.sample_rate * self.frame_s)
        stride = int(self.sample_rate * self.stride_s)
        return (1 + (n_samples - frame_len) // stride, self.n_fft // 2 + 1)

    def __call__(self, signal: jax.Array) -> jax.Array:
        frame_len = int(self.sample_rate * self.frame_s)
        stride = int(self.sample_rate * self.stride_s)
        frames = frame_signal(signal, frame_len, stride)
        window = jnp.asarray(np.hanning(frame_len), jnp.float32)
        cos, sin = fb.dft_matrices(frame_len, self.n_fft)
        xw = frames.astype(jnp.float32) * window
        re = xw @ jnp.asarray(cos)
        im = xw @ jnp.asarray(sin)
        return jnp.log(jnp.maximum(re * re + im * im, 1e-6))

    def hyperparams(self):
        return {"frame_s": self.frame_s, "stride_s": self.stride_s,
                "n_fft": self.n_fft}


@dataclasses.dataclass(frozen=True)
class RawBlock:
    """Pass-through (normalized raw signal) — the 'no DSP' end of the
    paper's continuum."""
    name: str = "raw"

    def feature_shape(self, n_samples: int) -> Tuple[int]:
        return (n_samples,)

    def __call__(self, signal: jax.Array) -> jax.Array:
        s = signal.astype(jnp.float32)
        mu = s.mean(axis=-1, keepdims=True)
        sd = s.std(axis=-1, keepdims=True) + 1e-6
        return (s - mu) / sd

    def hyperparams(self):
        return {}


@dataclasses.dataclass(frozen=True)
class ImageNormBlock:
    """Image scaling block for the VWW / image-classification pipelines."""
    name: str = "image_norm"

    def feature_shape(self, hwc: Tuple[int, int, int]):
        return hwc

    def __call__(self, images: jax.Array) -> jax.Array:
        return images.astype(jnp.float32) / 127.5 - 1.0

    def hyperparams(self):
        return {}
