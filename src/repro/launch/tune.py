import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# MUST precede all other imports (jax locks device count on first init).

# Pod-config tuner driver: the EON Tuner loop over distribution knobs.
#   python -m repro.launch.tune --arch dbrx-132b --shape train_4k --n 6
import argparse
import json
from pathlib import Path

from repro.core.tuner import PodConfigTuner
from repro.launch.dryrun import run_cell


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", required=True)
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    ap.add_argument("--n", type=int, default=6)
    ap.add_argument("--out", default="experiments/tuner")
    args = ap.parse_args()

    tuner = PodConfigTuner(run_cell, arch=args.arch, shape=args.shape,
                           multi_pod=args.mesh == "multi")
    ranked = tuner.search(n_samples=args.n)
    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)
    rows = []
    for c in ranked:
        r = c.report["roofline"]
        rows.append({"strategy": c.strategy, "n_micro": c.report["n_micro"],
                     "remat": c.remat,
                     "roofline_fraction": r["roofline_fraction"],
                     "bottleneck": r["bottleneck"],
                     "hbm_gib": c.report["memory"]["per_device_hbm_gib"]})
        print(rows[-1])
    (out / f"{args.arch}_{args.shape}_{args.mesh}.json").write_text(
        json.dumps(rows, indent=1))


if __name__ == "__main__":
    main()
