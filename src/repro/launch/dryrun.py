import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# The two lines above MUST run before any other import (jax locks the
# device count on first backend initialization).

# Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.
#
# For each cell this proves the distribution config is coherent on the
# production mesh (sharding propagation, collective legality, per-chip
# memory) and extracts the roofline terms — the platform's static
# resource-estimation stage (paper C2) applied to TPU pods.
#
# Usage:
#   python -m repro.launch.dryrun --arch internlm2-1.8b --shape train_4k
#   python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun

import argparse
import json
import time
import traceback
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from repro import configs, flags as perf_flags
from repro.core.arch import SHAPES, ArchConfig, ShapeConfig, shape_applicable
from repro.core.eon_compiler import normalize_cost_analysis
from repro.launch.mesh import make_production_mesh, mesh_name
from repro.models import api
from repro.models.params import abstract_params, logical_axes, param_count
from repro.roofline.collect import analyze_module, total_collective_bytes
from repro.roofline.model import (RooflineReport, fused_adjustment,
                                  model_flops)
from repro.sharding.policy import (AxisRules, logical_to_pspec, make_rules,
                                   params_pspecs)
from repro.serve.serve_step import make_decode_step, make_prefill_step
from repro.train.optimizer import abstract_opt_state
from repro.train.train_step import make_train_step

from jax.sharding import NamedSharding, PartitionSpec as P

# Archs whose q-head count does not divide the 16-way model axis use
# context-parallel attention; archs whose train activations overflow a
# 16 GiB chip under plain TP default to Megatron-SP (measured: qwen2
# 24.0→9.6 GiB, dbrx 16.1→12.6 GiB; see EXPERIMENTS.md §Perf).
DEFAULT_STRATEGY = {
    "gemma3-4b": "cp",       # 8 q heads
    "llama3.2-3b": "cp",     # 24 q heads
    "qwen2-vl-72b": "tp_sp",
    "dbrx-132b": "tp_sp",
}


def default_strategy(arch: str) -> str:
    return DEFAULT_STRATEGY.get(arch, "tp")


def default_n_micro(cfg: ArchConfig, shape: ShapeConfig, mesh) -> int:
    if shape.kind != "train":
        return 1
    dp = mesh.shape.get("data", 1) * mesh.shape.get("pod", 1)
    per_dp = 1 if param_count(cfg) > 2e10 else 2
    n = max(shape.global_batch // (dp * per_dp), 1)
    while shape.global_batch % n:
        n -= 1
    return n


# ---------------------------------------------------------------------------
# Sharding of inputs
# ---------------------------------------------------------------------------
def _batch_shardings(cfg, shape, mesh, rules, specs):
    axes = api.input_logical_axes(cfg, shape)
    return {
        name: NamedSharding(mesh, logical_to_pspec(
            axes[name], rules, mesh, specs[name].shape))
        for name in specs
    }


def cache_shardings(cfg, cache, mesh, rules):
    def assign(path, leaf):
        key = "/".join(str(getattr(p, "key", p)) for p in path).lower()
        nd = len(leaf.shape)
        if "pos" in key:
            axes = (None,) * (nd - 2) + ("act_batch", "act_cache_seq")
        elif "conv" in key:
            axes = (None,) * (nd - 3) + ("act_batch", None, "act_dinner")
        elif "ssm" in key:
            if nd >= 4:  # (..., B, di|nh, ds|P, [ds])
                tail = (("act_batch", "act_dinner", None, None) if nd >= 4
                        else ("act_batch", "act_dinner", None))
                tail = tail[:min(4, nd)]
                axes = (None,) * (nd - len(tail)) + tail
            else:
                axes = (None,) * nd
        else:
            axes = (None,) * (nd - 4) + ("act_batch", "act_cache_seq",
                                         "act_kv_heads", None)
        return NamedSharding(mesh, logical_to_pspec(axes, rules, mesh,
                                                    leaf.shape))
    return jax.tree_util.tree_map_with_path(assign, cache)


# ---------------------------------------------------------------------------
# Per-cell dry run
# ---------------------------------------------------------------------------
def run_cell(arch: str, shape_name: str, *, multi_pod: bool = False,
             strategy: Optional[str] = None, n_micro: Optional[int] = None,
             remat: str = "full", save_hlo: Optional[Path] = None,
             grad_compression: Optional[str] = None,
             opt_flags: Optional[Dict[str, bool]] = None) -> Dict[str, Any]:
    if opt_flags:
        perf_flags.set_flags(**opt_flags)
    cfg = configs.get(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return {"arch": arch, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skipped", "why": why}

    mesh = make_production_mesh(multi_pod=multi_pod)
    strategy = strategy or default_strategy(arch)
    rules = make_rules(strategy, multi_pod=multi_pod,
                       decode=shape.kind == "decode")
    n_micro = n_micro or default_n_micro(cfg, shape, mesh)

    t0 = time.time()
    aparams = abstract_params(cfg)
    plax = logical_axes(cfg)
    param_sh = params_pspecs(plax, rules, mesh, aparams)

    result: Dict[str, Any] = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name(mesh),
        "strategy": strategy, "n_micro": n_micro, "remat": remat,
        "n_chips": mesh.size, "params": param_count(cfg),
        "flags": dict(perf_flags.FLAGS),
    }

    if shape.kind == "train":
        specs = api.input_specs(cfg, shape)
        batch_sh = _batch_shardings(cfg, shape, mesh, rules, specs)
        aopt = abstract_opt_state(aparams)
        opt_sh = {"m": param_sh, "v": param_sh,
                  "step": NamedSharding(mesh, P())}
        step = make_train_step(cfg, n_microbatch=n_micro, remat=remat,
                               rules=rules, mesh=mesh,
                               grad_compression=grad_compression)
        jstep = jax.jit(step, in_shardings=(param_sh, opt_sh, batch_sh),
                        donate_argnums=(0, 1))
        lowered = jstep.lower(aparams, aopt, specs)
    elif shape.kind == "prefill":
        specs = api.input_specs(cfg, shape)
        batch_sh = _batch_shardings(cfg, shape, mesh, rules, specs)
        step = make_prefill_step(cfg, rules=rules, mesh=mesh)
        jstep = jax.jit(step, in_shardings=(param_sh, batch_sh))
        lowered = jstep.lower(aparams, specs)
    else:  # decode
        specs = api.input_specs(cfg, shape)
        cache_sh = cache_shardings(cfg, specs["cache"], mesh, rules)
        tok_sh = NamedSharding(mesh, logical_to_pspec(
            ("act_batch",), rules, mesh, specs["token"].shape))
        step = make_decode_step(cfg, rules=rules, mesh=mesh)
        jstep = jax.jit(step, in_shardings=(param_sh, cache_sh, tok_sh,
                                            tok_sh),
                        donate_argnums=(1,))
        lowered = jstep.lower(aparams, specs["cache"], specs["token"],
                              specs["position"])

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = normalize_cost_analysis(compiled.cost_analysis())
    hlo = compiled.as_text()
    wc = analyze_module(hlo)   # loop-weighted (cost_analysis is not)
    colls = {k: dict(v) for k, v in wc.collectives.items()}
    if save_hlo:
        save_hlo.parent.mkdir(parents=True, exist_ok=True)
        save_hlo.write_text(hlo)

    per_dev_hbm = (mem.argument_size_in_bytes + mem.temp_size_in_bytes
                   + mem.output_size_in_bytes - mem.alias_size_in_bytes
                   + mem.generated_code_size_in_bytes)
    rep = RooflineReport(
        arch=arch, shape=shape_name, mesh=result["mesh"],
        n_chips=mesh.size,
        hlo_flops=wc.flops,
        hlo_bytes=wc.bytes_accessed,
        hlo_bytes_min=wc.bytes_min,
        collective_bytes=total_collective_bytes(colls),
        collective_detail=colls,
        per_device_hbm=float(per_dev_hbm),
        model_flops=model_flops(cfg, shape),
    ).finalize()

    result.update({
        "status": "ok",
        "t_lower_s": round(t_lower, 2), "t_compile_s": round(t_compile, 2),
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
            "per_device_hbm_bytes": per_dev_hbm,
            "per_device_hbm_gib": round(per_dev_hbm / 2**30, 3),
        },
        "cost": {"flops_per_device": rep.hlo_flops,
                 "bytes_per_device": rep.hlo_bytes,
                 "xla_cost_analysis_flops_unweighted":
                     float(cost.get("flops", 0.0))},
        "collectives": colls,
        "roofline": {**rep.row(), **fused_adjustment(cfg, shape, rep)},
        "model_flops": rep.model_flops,
    })
    return result


def print_summary(res: Dict[str, Any]) -> None:
    if res.get("status") == "skipped":
        print(f"[skip] {res['arch']} x {res['shape']} x {res['mesh']}: "
              f"{res['why']}")
        return
    r = res["roofline"]
    print(f"[ok]   {res['arch']} x {res['shape']} x {res['mesh']} "
          f"strat={res['strategy']} micro={res['n_micro']} "
          f"lower={res['t_lower_s']}s compile={res['t_compile_s']}s")
    print(f"       hbm/dev={res['memory']['per_device_hbm_gib']} GiB "
          f"fits={r['fits_hbm']}  bottleneck={r['bottleneck']}")
    print(f"       t_comp={r['t_compute_s']}s t_mem={r['t_memory_s']}s "
          f"t_coll={r['t_collective_s']}s useful={r['useful_flops_ratio']} "
          f"roofline_frac={r['roofline_fraction']}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--strategy", default=None)
    ap.add_argument("--micro", type=int, default=None)
    ap.add_argument("--remat", default="full")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose JSON already exists")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--save-hlo", action="store_true")
    ap.add_argument("--grad-compression", default=None)
    ap.add_argument("--opt", action="store_true",
                    help="enable beyond-paper perf flags "
                         "(bf16_params + bf16_attn_p)")
    args = ap.parse_args()
    if args.opt:
        perf_flags.set_flags(bf16_params=True, bf16_attn_p=True)

    out = Path(args.out)
    out.mkdir(parents=True, exist_ok=True)

    archs = list(configs.ALIASES) if (args.all or not args.arch) \
        else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    failures = []
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                tag = f"{arch}_{shape_name}_{'multi' if multi else 'single'}"
                path = out / f"{tag}.json"
                if args.resume and path.exists():
                    print(f"[resume] {tag} exists")
                    continue
                try:
                    res = run_cell(
                        arch, shape_name, multi_pod=multi,
                        strategy=args.strategy, n_micro=args.micro,
                        remat=args.remat,
                        grad_compression=args.grad_compression,
                        save_hlo=(out / f"{tag}.hlo.txt"
                                  if args.save_hlo else None))
                except Exception as e:  # a failure here is a bug — record it
                    res = {"arch": arch, "shape": shape_name,
                           "mesh": "2x16x16" if multi else "16x16",
                           "status": "error", "error": str(e)[:2000],
                           "traceback": traceback.format_exc()[-4000:]}
                    failures.append(tag)
                path.write_text(json.dumps(res, indent=1))
                if res["status"] == "error":
                    print(f"[FAIL] {tag}: {res['error'][:200]}")
                else:
                    print_summary(res)
    if failures:
        print(f"\n{len(failures)} FAILURES: {failures}")
        raise SystemExit(1)


if __name__ == "__main__":
    main()
