"""Elastic scaling: rebuild a smaller/larger mesh and reshard state.

At 1000+ nodes, node loss is routine: the runbook is (1) detect (trainer
watchdog / heartbeat), (2) checkpoint-or-use-latest, (3) rebuild a mesh
from surviving hosts, (4) restore with resharding (the checkpointer
stores global shapes, so any mesh whose axes divide them works),
(5) rescale the data pipeline's host shards.  This module implements the
mesh arithmetic + restore plumbing; tests exercise a full
kill→shrink→resume cycle on the host platform.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
from jax.sharding import Mesh

try:  # jax >= 0.5: explicit/auto axis types on meshes
    from jax.sharding import AxisType
except ImportError:  # older jax: meshes are implicitly "auto"
    AxisType = None

from repro.checkpoint.checkpointer import Checkpointer
from repro.sharding.policy import AxisRules, params_pspecs


@dataclasses.dataclass
class ElasticPlan:
    old_shape: Dict[str, int]
    new_shape: Dict[str, int]
    note: str


def plan_rescale(mesh_shape: Dict[str, int], surviving_devices: int,
                 *, keep_model_axis: bool = True) -> ElasticPlan:
    """Choose a new mesh shape for the surviving device count.

    Policy: preserve the "model" axis (TP degree is baked into layouts
    and kernel tile choices); shrink the DP axes ("pod" first, then
    "data") to the largest power-of-two fit.  This keeps per-device
    param shards identical, so restore is a pure re-placement for
    params and only the DP-sharded activations change shape.
    """
    model = mesh_shape.get("model", 1)
    assert surviving_devices >= model, "fewer devices than TP degree"
    dp_budget = surviving_devices // model
    # largest power of two <= dp_budget
    dp = 1
    while dp * 2 <= dp_budget:
        dp *= 2
    new: Dict[str, int] = {}
    if "pod" in mesh_shape and dp >= mesh_shape["data"]:
        new["pod"] = dp // mesh_shape["data"]
        new["data"] = mesh_shape["data"]
    else:
        new["data"] = dp
    new["model"] = model
    return ElasticPlan(dict(mesh_shape), new,
                       note=f"rescale {mesh_shape} -> {new} "
                            f"({surviving_devices} devices survive)")


def build_mesh(shape: Dict[str, int]) -> Mesh:
    axes = tuple(shape.keys())
    dims = tuple(shape.values())
    if AxisType is None:
        return jax.make_mesh(dims, axes)
    return jax.make_mesh(dims, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def elastic_restore(ckpt: Checkpointer, tree_like, rules: AxisRules,
                    logical_tree, new_mesh: Mesh,
                    step: Optional[int] = None):
    """Restore the latest checkpoint resharded onto ``new_mesh``."""
    shardings = params_pspecs(logical_tree, rules, new_mesh,
                              shapes_tree=tree_like)
    return ckpt.restore(tree_like, step, shardings)
