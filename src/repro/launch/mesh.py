"""Production mesh construction.

A function, not a module-level constant: importing this module never
touches jax device state (device count is locked on first backend init).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 (512 chips, 2 pods).

    Axes: "pod" (DP-outer, crosses DCN), "data" (DP/FSDP, intra-pod ICI),
    "model" (TP/EP/SP, innermost — fastest ICI neighbours).
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh():
    """Whatever devices exist (tests/examples on CPU): 1-device mesh."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


def mesh_name(mesh) -> str:
    return "x".join(str(mesh.shape[a]) for a in mesh.axis_names)
