"""Serving driver: ``python -m repro.launch.serve --arch <id>``.

Runs the continuous-batching server over synthetic prompts on the
selected arch (smoke config on CPU; same code takes the full config on
a pod).  ``--engine static`` selects the static-batching baseline,
``--engine paged`` the paged-KV-pool engine (block tables, prefix
sharing, preempt-and-recompute — docs/paged_kv.md; ``--pool-blocks``
sizes the pool below the contiguous rectangle), ``--artifact`` runs the
decode hot loop from an AOT ``CompiledArtifact`` (paper C4: serve the
deployed executable).
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro import configs
from repro.models.params import init_params
from repro.serve.server import (ContinuousBatchServer, PagedBatchServer,
                                StaticBatchServer)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--engine", choices=("continuous", "static", "paged"),
                    default="continuous")
    ap.add_argument("--pool-blocks", type=int, default=None,
                    help="paged engine: physical KV blocks in the pool"
                         " (default: the contiguous rectangle's count)")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", "--batch", dest="slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--prefill-chunk", type=int, default=8,
                    help="chunked pad-free admission: prompt tokens per"
                         " prefill chunk step (docs/scheduling.md)")
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--artifact", action="store_true",
                    help="decode via AOT CompiledArtifact (EON-style)")
    ap.add_argument("--precision", choices=("float", "int8"),
                    default="float",
                    help="int8: QTensor weights + dynamic activation quant"
                         " + Int8KV cache (paper C5 end-to-end)")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args()

    cfg = configs.get(args.arch) if args.full else configs.get_smoke(args.arch)
    params = init_params(cfg, jax.random.key(0))
    if args.engine == "static":
        server = StaticBatchServer(cfg, params, batch_size=args.slots,
                                   max_prompt=args.prompt_len,
                                   prefill_chunk=args.prefill_chunk,
                                   max_new_tokens=args.max_new,
                                   precision=args.precision)
    elif args.engine == "paged":
        server = PagedBatchServer(
            cfg, params, slots=args.slots, max_prompt=args.prompt_len,
            prefill_chunk=args.prefill_chunk,
            max_new_tokens=args.max_new, use_artifact=args.artifact,
            pool_blocks=args.pool_blocks, precision=args.precision)
    else:
        server = ContinuousBatchServer(
            cfg, params, slots=args.slots, max_prompt=args.prompt_len,
            prefill_chunk=args.prefill_chunk,
            max_new_tokens=args.max_new, use_artifact=args.artifact,
            precision=args.precision)
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, size=args.prompt_len)
               .astype(np.int32) for _ in range(args.requests)]
    server.submit(prompts)
    metrics = server.run()
    print(json.dumps(metrics, indent=1))


if __name__ == "__main__":
    main()
