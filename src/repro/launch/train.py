"""Training driver: ``python -m repro.launch.train --arch <id> [...]``.

On CPU this trains reduced/smoke configs (the end-to-end example path);
on a real pod the same driver takes the full config + production mesh.
Checkpoint/restart, LR schedule, watchdog and best-model restore come
from the Trainer.
"""
from __future__ import annotations

import argparse
import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.core.arch import ShapeConfig
from repro.data.synthetic import lm_batches, token_stream
from repro.models.params import init_params, param_count
from repro.train.optimizer import AdamWConfig, adamw_init
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


def build(arch: str, *, smoke: bool, batch: int, seq: int, n_micro: int,
          lr: float, grad_compression: str | None, remat: str):
    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    params = init_params(cfg, jax.random.key(0))
    opt_state = adamw_init(params)
    step = make_train_step(
        cfg, n_microbatch=n_micro, remat=remat,
        opt=AdamWConfig(lr=lr),
        grad_compression=grad_compression)
    return cfg, params, opt_state, jax.jit(step, donate_argnums=(0, 1))


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--full", dest="smoke", action="store_false")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--micro", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--remat", default="none")
    ap.add_argument("--grad-compression", default=None)
    ap.add_argument("--ckpt-dir", default="checkpoints/train")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()

    cfg, params, opt_state, step = build(
        args.arch, smoke=args.smoke, batch=args.batch, seq=args.seq,
        n_micro=args.micro, lr=args.lr,
        grad_compression=args.grad_compression, remat=args.remat)
    print(f"arch={cfg.name} params={param_count(cfg):,}")

    tokens = token_stream(200_000, cfg.vocab_size, seed=1)
    batches = lm_batches(tokens, args.batch, args.seq)

    trainer = Trainer(step, params, opt_state,
                      ckpt_dir=Path(args.ckpt_dir),
                      config=TrainerConfig(total_steps=args.steps,
                                           checkpoint_every=args.ckpt_every,
                                           log_every=10))
    if args.resume:
        resumed = trainer.maybe_resume()
        print("resumed from checkpoint" if resumed else "fresh start")
    result = trainer.run(iter(batches))
    print(f"final loss {result['final_loss']:.4f} "
          f"(best {result['best']['loss']:.4f} @ {result['best']['step']})")
    if args.out:
        Path(args.out).write_text(json.dumps(
            {"arch": cfg.name, "final": result["final_loss"],
             "best": result["best"], "steps": args.steps,
             "history_tail": result["history"][-5:]}, indent=1))


if __name__ == "__main__":
    main()
