"""Slot scheduler for the continuous-batching engines (paper §4.6).

The serving analogue of the EIM process runner's queue: requests wait in
an FCFS queue; a fixed set of KV-cache *slots* is the unit of admission.
A slot's lifecycle is

    FREE ──admit──▶ PREFILLING ──last chunk──▶ ACTIVE ──finish──▶ FREE
         (reset_slot)   (chunk steps,        (decode steps)  (release_slot)
                         budgeted per            │
                         decode step)            │ pool dry (paged)
                                                 ▼
                                            PREEMPTED ──▶ back to queue
                                            (blocks freed; re-admitted
                                             FCFS-front and re-prefilled
                                             over prompt ++ generated)

Admission is cheap (host bookkeeping plus one device-side slot-row
reset — no prefill compute): the prompt is then consumed in fixed-size
chunks *interleaved with decode steps* under a per-step token budget,
each chunk written unpadded into the slot's cache rows — no pad row
ever occupies KV capacity, and a long prompt can never
head-of-line-block the active slots' next tokens.  Slots are freed
*between decode steps*, not at batch boundaries, so a short request
never waits for the longest member of its batch — that is the whole
difference between continuous and static batching.

Under the **paged** engine the admission gate is the free-block
watermark of the KV pool, not merely a free slot: a request is admitted
only when the pool covers its prompt's blocks (minus any prefix-cached
blocks it can share), and when the pool later runs dry mid-decode the
*youngest* slot is PREEMPTED — its blocks freed, its request re-queued
at the FCFS front carrying the tokens it already generated, to be
re-prefilled over ``prompt ++ generated`` (preempt-and-recompute; greedy
decoding makes the recompute token-exact).  ``Slot.blocks`` is the
host-side block-table row backing all of this (docs/paged_kv.md).

See docs/scheduling.md for the full lifecycle/budget contract.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Tuple

import numpy as np


@dataclasses.dataclass
class Slot:
    """Host-side view of one decode-cache row.

    Invariants (the ``kv_len`` contract the decode kernel relies on):
    cache rows ``[0, fill)`` hold this request's live KV, rows at index
    ``>= fill`` are invalid (position −1, or garbage behind the kv_len
    bound); with pad-free admission the cache index of every entry
    equals its absolute position, so ``write_idx == position`` and the
    post-write fill is ``position + 1``.
    """
    index: int
    rid: Optional[int] = None      # request occupying the slot (None = free)
    prompt: Optional[np.ndarray] = None   # host copy while PREFILLING
    chunk_pos: int = 0             # prompt tokens already prefilled
    position: int = 0              # absolute position of the next token
    generated: int = 0             # tokens emitted for this request
    max_new: int = 0
    # paged engine only: physical KV block ids in logical order — the
    # host mirror of this slot's block-table row (prefix-shared blocks,
    # which carry extra refcounts, sit at the front; `chunk_pos` starts
    # past them).
    blocks: List[int] = dataclasses.field(default_factory=list)

    @property
    def write_idx(self) -> int:
        """Cache row of the next decode write — identically ``position``
        under pad-free admission (derived, so the two can never drift)."""
        return self.position

    @property
    def free(self) -> bool:
        return self.rid is None

    @property
    def prefilling(self) -> bool:
        return self.rid is not None and self.prompt is not None

    @property
    def active(self) -> bool:
        return self.rid is not None and self.prompt is None

    def occupy(self, rid: int, prompt: np.ndarray, max_new: int) -> None:
        """FREE → PREFILLING: park the prompt; no device work yet."""
        self.rid = rid
        self.prompt = np.asarray(prompt, np.int32)
        self.chunk_pos = 0
        self.generated = 0
        self.max_new = max_new

    def begin_decode(self) -> None:
        """PREFILLING → ACTIVE: the final chunk emitted the first token
        (position ``len(prompt) − 1``), so decoding starts at
        ``position == write_idx == len(prompt)``."""
        plen = len(self.prompt)
        self.prompt = None
        self.position = plen
        self.generated = 1           # the prefill's greedy token counts

    def advance(self) -> None:
        self.position += 1
        self.generated += 1

    def release(self) -> None:
        self.rid = None
        self.prompt = None
        self.chunk_pos = 0
        self.generated = 0
        self.max_new = 0
        self.blocks = []


class SlotScheduler:
    """FCFS admission over a fixed slot set."""

    def __init__(self, n_slots: int):
        self.slots: List[Slot] = [Slot(i) for i in range(n_slots)]
        self.waiting: Deque = deque()

    def enqueue(self, req) -> None:
        self.waiting.append(req)

    def free_slots(self) -> List[Slot]:
        return [s for s in self.slots if s.free]

    def prefilling_slots(self) -> List[Slot]:
        return [s for s in self.slots if s.prefilling]

    def active_slots(self) -> List[Slot]:
        return [s for s in self.slots if s.active]

    def admissions(self) -> List[Tuple[Slot, object]]:
        """Pair waiting requests with free slots (drains either side)."""
        out = []
        for slot in self.free_slots():
            if not self.waiting:
                break
            out.append((slot, self.waiting.popleft()))
        return out

    def requeue_front(self, req) -> None:
        """PREEMPTED re-entry: a preempted request outranks every
        waiting one (it has already consumed service), so it re-enters
        at the FCFS front and is re-admitted as soon as the pool covers
        its re-prefill."""
        self.waiting.appendleft(req)

    def preemption_victim(self) -> Optional[Slot]:
        """The youngest occupied slot (highest rid — least service
        received under FCFS admission).  The paged engine evicts this
        slot when the pool runs dry; the victim may be the slot whose
        growth triggered the eviction (it then skips its decode step)."""
        held = [s for s in self.slots if not s.free]
        if not held:
            return None
        return max(held, key=lambda s: s.rid)

    @property
    def busy(self) -> bool:
        return bool(self.waiting) or any(not s.free for s in self.slots)
