"""Slot scheduler for the continuous-batching engine (paper §4.6).

The serving analogue of the EIM process runner's queue: requests wait in
an FCFS queue; a fixed set of KV-cache *slots* (rows of the decode
cache) is the unit of admission.  A slot's lifecycle is

    FREE ──admit──▶ ACTIVE ──finish──▶ FREE
          (prefill + write_slot)   (release_slot between decode steps)

Slots are freed *between decode steps*, not at batch boundaries, so a
short request never waits for the longest member of its batch — that is
the whole difference between continuous and static batching.

``BucketPolicy`` quantises prompt lengths to a small set of padded
prefill shapes so each bucket compiles exactly once.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, List, Optional, Sequence, Tuple


class BucketPolicy:
    """Smallest-fitting padded prefill bucket; prompts longer than the
    largest bucket are truncated (keep the most recent tokens)."""

    def __init__(self, buckets: Sequence[int]):
        assert buckets, "need at least one prefill bucket"
        self.buckets: Tuple[int, ...] = tuple(sorted(set(int(b)
                                                         for b in buckets)))

    @property
    def max_bucket(self) -> int:
        return self.buckets[-1]

    def bucket_for(self, prompt_len: int) -> int:
        for b in self.buckets:
            if prompt_len <= b:
                return b
        return self.max_bucket


@dataclasses.dataclass
class Slot:
    """Host-side view of one decode-cache row."""
    index: int
    rid: Optional[int] = None      # request occupying the slot (None = free)
    position: int = 0              # absolute position of the next token
    write_idx: int = 0             # next free cache row index (≥ bucket)
    generated: int = 0             # tokens emitted for this request
    max_new: int = 0

    @property
    def free(self) -> bool:
        return self.rid is None

    def occupy(self, rid: int, prompt_len: int, bucket: int,
               max_new: int) -> None:
        self.rid = rid
        self.position = prompt_len   # prefill emitted the token at len-1
        self.write_idx = bucket
        self.generated = 1           # prefill's greedy token counts
        self.max_new = max_new

    def advance(self) -> None:
        self.position += 1
        self.write_idx += 1
        self.generated += 1

    def release(self) -> None:
        self.rid = None
        self.generated = 0
        self.max_new = 0


class SlotScheduler:
    """FCFS admission over a fixed slot set."""

    def __init__(self, n_slots: int):
        self.slots: List[Slot] = [Slot(i) for i in range(n_slots)]
        self.waiting: Deque = deque()

    def enqueue(self, req) -> None:
        self.waiting.append(req)

    def free_slots(self) -> List[Slot]:
        return [s for s in self.slots if s.free]

    def active_slots(self) -> List[Slot]:
        return [s for s in self.slots if not s.free]

    def admissions(self) -> List[Tuple[Slot, object]]:
        """Pair waiting requests with free slots (drains either side)."""
        out = []
        for slot in self.free_slots():
            if not self.waiting:
                break
            out.append((slot, self.waiting.popleft()))
        return out

    @property
    def busy(self) -> bool:
        return bool(self.waiting) or any(not s.free for s in self.slots)
