"""KV-cache utilities (re-exported from the backbone + sizing helpers).

Cache construction lives with the model (transformer._cache_from_prefill)
so layouts stay next to the attention code; this module adds the
serving-side arithmetic the server and estimator need.
"""
from __future__ import annotations

from typing import Dict

from repro.core.arch import ArchConfig
from repro.models.transformer import grow_cache  # noqa: F401  (re-export)


def kv_cache_bytes(cfg: ArchConfig, batch: int, seq_len: int,
                   dtype_bytes: int = 2) -> int:
    """Global KV/state cache footprint for one decode session."""
    hd = cfg.resolved_head_dim
    if cfg.family == "ssm":
        conv = batch * (cfg.d_conv - 1) * cfg.d_inner * dtype_bytes
        h = batch * cfg.d_inner * cfg.ssm_state * 4
        return cfg.n_layers * (conv + h)
    if cfg.family == "hybrid":
        nh = cfg.resolved_ssm_heads
        hp = cfg.d_inner // nh
        conv = batch * (cfg.d_conv - 1) * cfg.d_inner * dtype_bytes
        h = batch * nh * hp * cfg.ssm_state * 4
        n_attn = cfg.n_layers // max(cfg.attn_every, 1)
        kv = n_attn * 2 * batch * seq_len * cfg.n_kv_heads * hd * dtype_bytes
        return cfg.n_layers * (conv + h) + kv
    per_layer_kv = 2 * batch * cfg.n_kv_heads * hd * dtype_bytes
    if cfg.sliding_window and cfg.local_global_ratio:
        r = cfg.local_global_ratio
        n_global = cfg.n_layers // (r + 1)
        n_local = cfg.n_layers - n_global
        return (n_global * per_layer_kv * seq_len
                + n_local * per_layer_kv * min(cfg.sliding_window, seq_len))
    n_layers = cfg.n_layers + (cfg.n_enc_layers if cfg.is_encdec else 0) * 0
    total = n_layers * per_layer_kv * seq_len
    if cfg.is_encdec:
        total += cfg.n_layers * per_layer_kv * (seq_len // cfg.enc_seq_divisor)
    return total
