"""KV-cache utilities: sizing arithmetic, the slot API the continuous-
batching engine is built on, and the **paged KV pool** (block table +
``BlockManager``) the paged engine is built on.

Cache construction lives with the model (transformer._cache_from_prefill)
so layouts stay next to the attention code; this module adds the
serving-side pieces:

* ``kv_cache_bytes``        — footprint arithmetic (estimator/server).
* ``kv_block_size``         — the KV block granularity (canonical home;
                              the kernels, both engines' capacity
                              rounding, and the paged pool's physical
                              block size all share this one helper).
* ``alloc_decode_cache``    — zero-filled slot-addressed decode cache of
                              ``slots`` rows × ``capacity`` KV entries,
                              position arrays initialised to -1 (invalid).
* ``slot_batch_axes`` / ``take_slot`` / ``put_slot`` — the slot-view API
  chunked pad-free prefill is built on: slice one slot's row out of the
  big cache (a batch-1 sub-cache), run a prefill chunk against it, and
  splice it back.  Admission resets a slot by ``put_slot``-ing an empty
  batch-1 cache in (positions −1, SSM state zeroed).
* ``release_slot``          — invalidate a slot row's positions so stale
                              KV can never be attended (the free path).
* ``abstract_decode_cache`` — ShapeDtypeStructs of the above, for AOT
                              export (eon_compiler.compile_serve_decode).

Paged layout (docs/paged_kv.md): the full-attention KV leaves trade
their per-slot ``capacity`` rectangle for a global pool of ``num_blocks``
fixed-size blocks — leaf (*L, B, S, Hkv, D) becomes (*L, NB, BS, Hkv,
D), positions move to a (NB, BS) ``pool_pos`` pool — addressed through a
per-slot **block table** (B, capacity // BS).  Sliding-window ring
caches and SSM state stay slot-addressed (they are O(window)/O(state)
per slot — there is no capacity tail to reclaim).  ``BlockManager`` owns
allocation: free-list, per-block refcounts, hash-chain prefix caching
(identical prompt prefixes share physical blocks at block granularity),
and LRU reclaim of cached-but-unreferenced blocks; preempt-and-recompute
lives in the scheduler/server on top of it.

Validity is decided by stored positions (−1 = empty) plus the
scheduler's per-slot ``kv_len`` bound, so a slot row can be recycled
between decode steps without touching the K/V bytes — and, in the paged
layout, so a physical block can be handed to a new tenant without being
scrubbed (the new tenant's writes precede its ``kv_len``).

Every entry point is precision-aware (``PrecisionPolicy``): an int8
policy makes the KV leaves ``Int8KV`` pairs — int8 values plus one f32
scale per (entry, head) — and the slot API splices/releases/sizes the
paired pytree; ``decode_cache_nbytes`` measures the HBM delta.
"""
from __future__ import annotations

import hashlib
from collections import OrderedDict, deque
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.arch import ArchConfig, ShapeConfig
from repro.core.quantize import PrecisionPolicy
# canonical block-granularity helper (defined next to the kernels it
# must agree with; this module is its serving-side home)
from repro.kernels.flash_decode import kv_block_size  # noqa: F401


def kv_cache_bytes(cfg: ArchConfig, batch: int, seq_len: int,
                   dtype_bytes: int = 2, *,
                   precision: str = "float") -> int:
    """Global KV/state cache footprint for one decode session.

    ``precision="int8"`` prices the Int8KV layout: 1 byte per value plus
    one f32 scale per (entry, head) vector of ``head_dim`` values —
    attention KV only; SSM recurrent state stays float either way.
    """
    hd = cfg.resolved_head_dim
    # bytes per stored attention-KV scalar; the int8 layout adds one f32
    # scale per head-vector of hd values.  SSM conv/recurrent state stays
    # float under every precision.
    kv_bytes = (hd + 4) / hd if precision == "int8" else dtype_bytes
    if cfg.family == "ssm":
        conv = batch * (cfg.d_conv - 1) * cfg.d_inner * dtype_bytes
        h = batch * cfg.d_inner * cfg.ssm_state * 4
        return int(cfg.n_layers * (conv + h))
    if cfg.family == "hybrid":
        nh = cfg.resolved_ssm_heads
        hp = cfg.d_inner // nh
        conv = batch * (cfg.d_conv - 1) * cfg.d_inner * dtype_bytes
        h = batch * nh * hp * cfg.ssm_state * 4
        n_attn = cfg.n_layers // max(cfg.attn_every, 1)
        kv = n_attn * 2 * batch * seq_len * cfg.n_kv_heads * hd * kv_bytes
        return int(cfg.n_layers * (conv + h) + kv)
    per_layer_kv = 2 * batch * cfg.n_kv_heads * hd * kv_bytes
    if cfg.sliding_window and cfg.local_global_ratio:
        r = cfg.local_global_ratio
        n_global = cfg.n_layers // (r + 1)
        n_local = cfg.n_layers - n_global
        return int(n_global * per_layer_kv * seq_len
                   + n_local * per_layer_kv * min(cfg.sliding_window, seq_len))
    # Enc-dec: encoder layers hold no decode-time cache (the encoder runs
    # once; its output *is* the cross KV).  The decoder holds self-attn KV
    # over seq_len plus cross-attn KV over the subsampled encoder length.
    total = cfg.n_layers * per_layer_kv * seq_len
    if cfg.is_encdec:
        total += cfg.n_layers * per_layer_kv * (seq_len // cfg.enc_seq_divisor)
    return int(total)


# ---------------------------------------------------------------------------
# Slot-addressed decode cache (continuous batching)
# ---------------------------------------------------------------------------
def abstract_decode_cache(cfg: ArchConfig, slots: int, capacity: int,
                          policy: Optional[PrecisionPolicy] = None):
    """ShapeDtypeStructs of a ``slots`` × ``capacity`` decode cache.
    With an int8 ``policy`` the KV leaves come back as Int8KV pairs."""
    from repro.models.api import abstract_cache
    shape = ShapeConfig("serve_alloc", seq_len=capacity, global_batch=slots,
                        kind="prefill")
    return abstract_cache(cfg, shape, policy)


def decode_cache_nbytes(cache) -> int:
    """HBM footprint of a (concrete or abstract) decode-cache pytree —
    every leaf: KV values, Int8KV scales, position bookkeeping."""
    return sum(int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
               for leaf in jax.tree.leaves(cache))


def _concrete_empty(abs_cache):
    """Zeros everywhere, −1 in position leaves (the empty marker)."""
    def init(key_path, sds):
        name = key_path[0].key if hasattr(key_path[0], "key") else None
        if name is not None and name.endswith("_pos"):
            return jnp.full(sds.shape, -1, sds.dtype)
        return jnp.zeros(sds.shape, sds.dtype)

    return jax.tree_util.tree_map_with_path(init, abs_cache)


def alloc_decode_cache(cfg: ArchConfig, slots: int, capacity: int,
                       policy: Optional[PrecisionPolicy] = None):
    """Concrete all-empty decode cache: zeros, positions −1 (invalid)."""
    return _concrete_empty(abstract_decode_cache(cfg, slots, capacity,
                                                 policy))


def _first_diff_axis(big_shape, small_shape) -> int:
    """Axis where a batch-1 sub-cache differs from the full cache (the
    batch axis — it always precedes any seq-length difference)."""
    for i, (b, s) in enumerate(zip(big_shape, small_shape)):
        if b != s:
            return i
    return -1  # identical shapes: slots == 1, write in place


def slot_batch_axes(cfg: ArchConfig, slots: int, capacity: int,
                    policy: Optional[PrecisionPolicy] = None):
    """Per-leaf batch-axis pytree of the decode cache, inferred by
    diffing the ``slots``-row abstract cache against its batch-1 twin —
    robust to every layout (stacked-layer KV, Int8KV value/scale pairs,
    nested SSM state).  Computed once per server; closed over (static)
    by the jitted slot-view steps.  −1 marks a leaf with no batch axis
    (only possible when ``slots == 1``: slice/splice in place)."""
    big = abstract_decode_cache(cfg, slots, capacity, policy)
    small = abstract_decode_cache(cfg, 1, capacity, policy)
    return jax.tree.map(lambda b, s: _first_diff_axis(b.shape, s.shape),
                        big, small)


def take_slot(big_cache, axes, slot):
    """Slice slot ``slot``'s row out of the big cache as a batch-1
    sub-cache (``axes`` from ``slot_batch_axes``, closed over — the axis
    choice must be static under jit; ``slot`` may be traced)."""
    def take(big, axis):
        if axis < 0:
            return big
        starts = [0] * big.ndim
        starts[axis] = slot
        sizes = list(big.shape)
        sizes[axis] = 1
        return lax.dynamic_slice(big, tuple(starts), tuple(sizes))
    return jax.tree.map(take, big_cache, axes)


def put_slot(big_cache, small_cache, axes, slot):
    """Splice a batch-1 sub-cache back into row ``slot`` — the inverse
    of ``take_slot``.  Splicing a fresh ``alloc_decode_cache(cfg, 1, …)``
    resets the slot (positions −1, SSM state zeroed) for admission."""
    def put(big, small, axis):
        starts = [0] * big.ndim
        if axis >= 0:
            starts[axis] = slot
        return lax.dynamic_update_slice(big, small.astype(big.dtype),
                                        tuple(starts))
    return jax.tree.map(put, big_cache, small_cache, axes)


def release_slot(big_cache: Dict[str, Any], slot) -> Dict[str, Any]:
    """Invalidate a slot row: set its position entries to −1.  K/V bytes
    stay in place — they are unreachable once no position marks them.
    (``pool_pos`` is pool-addressed, not per-slot, and is skipped: paged
    reuse is fenced by ``kv_len``, not by scrubbing — see
    docs/paged_kv.md.)"""
    out = dict(big_cache)
    for key, big in big_cache.items():
        if key.endswith("_pos") and key != "pool_pos":
            row = jnp.full((1, big.shape[1]), -1, big.dtype)
            out[key] = lax.dynamic_update_slice(big, row, (slot, 0))
    return out


# ---------------------------------------------------------------------------
# Paged KV pool (block table + BlockManager) — docs/paged_kv.md
# ---------------------------------------------------------------------------
_PAGED_KEYS = {
    "uniform_dense": ("k", "v"),
    "uniform_moe": ("k", "v"),
    "local_global": ("global_k", "global_v"),
    "hybrid": ("attn_k", "attn_v"),
    "uniform_ssm": (),
}


def paged_cache_keys(cfg: ArchConfig) -> Tuple[str, ...]:
    """Cache keys that live in the paged pool for this architecture:
    exactly the full-attention KV leaves.  Sliding-window rings and SSM
    state stay slot-addressed (fixed O(window)/O(state) per slot), and a
    pure-SSM family pages nothing at all."""
    from repro.models.params import layer_pattern
    return _PAGED_KEYS[layer_pattern(cfg)["kind"]]


def abstract_paged_cache(cfg: ArchConfig, slots: int, capacity: int,
                         num_blocks: int,
                         policy: Optional[PrecisionPolicy] = None,
                         block_size: Optional[int] = None):
    """ShapeDtypeStructs of a paged decode cache: full-attention KV
    leaves as (*L, num_blocks, BS, Hkv, D) pools + an (num_blocks, BS)
    ``pool_pos`` position pool, everything else (ring caches, SSM state,
    ``local_pos``) as the usual ``slots``-row slot leaves.  BS defaults
    to ``kv_block_size(capacity)`` (the kernel tile — maximum DMA
    efficiency) and may be overridden by any divisor of ``capacity``
    that still tiles (≥ 8) for finer-grained pooling; the block table
    itself is host state (a (slots, capacity // BS) int32 operand, not
    a cache leaf)."""
    bs = block_size or kv_block_size(capacity)
    assert capacity % bs == 0 and bs >= 8, (capacity, bs)
    slot_abs = abstract_decode_cache(cfg, slots, capacity, policy)
    keys = paged_cache_keys(cfg)
    cache = {k: v for k, v in slot_abs.items()
             if k not in keys and k != "full_pos"}
    if keys:
        # a pool is structurally a "cache of num_blocks slots of BS rows"
        pool_abs = abstract_decode_cache(cfg, num_blocks, bs, policy)
        for k in keys:
            cache[k] = pool_abs[k]
        cache["pool_pos"] = pool_abs["full_pos"]
    return cache


def alloc_paged_cache(cfg: ArchConfig, slots: int, capacity: int,
                      num_blocks: int,
                      policy: Optional[PrecisionPolicy] = None,
                      block_size: Optional[int] = None):
    """Concrete all-empty paged decode cache (zeros, positions −1)."""
    return _concrete_empty(abstract_paged_cache(cfg, slots, capacity,
                                                num_blocks, policy,
                                                block_size))


def paged_slot_axes(cfg: ArchConfig, slots: int, capacity: int,
                    num_blocks: int,
                    policy: Optional[PrecisionPolicy] = None,
                    block_size: Optional[int] = None):
    """Per-leaf batch-axis pytree for the *paged* cache, consumed by
    ``take_slot``/``put_slot``: slot-addressed leaves carry their batch
    axis as in ``slot_batch_axes``; pool leaves (and ``pool_pos``) carry
    −1 — "no slot axis", which those helpers already treat as take-whole
    / splice-whole, exactly what a globally shared pool needs."""
    cache = abstract_paged_cache(cfg, slots, capacity, num_blocks, policy,
                                 block_size)
    small = abstract_decode_cache(cfg, 1, capacity, policy)
    shared = set(paged_cache_keys(cfg)) | {"pool_pos"}
    axes: Dict[str, Any] = {}
    for key, leaf in cache.items():
        if key in shared:
            axes[key] = jax.tree.map(lambda _: -1, leaf)
        else:
            axes[key] = jax.tree.map(
                lambda b, s: _first_diff_axis(b.shape, s.shape),
                leaf, small[key])
    return axes


def kv_pool_block_bytes(cfg: ArchConfig, capacity: int,
                        policy: Optional[PrecisionPolicy] = None,
                        block_size: Optional[int] = None) -> int:
    """HBM bytes one physical KV block occupies across all paged leaves
    (KV values, Int8KV scales, its ``pool_pos`` row) — the per-block
    price the pool's live-block accounting multiplies out."""
    keys = paged_cache_keys(cfg)
    if not keys:
        return 0
    bs = block_size or kv_block_size(capacity)
    # pass bs as the explicit block size too: a one-block pool of
    # capacity bs would otherwise re-derive kv_block_size(bs), which
    # differs whenever bs > 128 (kv_block_size(256) == 128)
    pool = abstract_paged_cache(cfg, 1, bs, 1, policy, bs)
    leaves = [pool[k] for k in keys] + [pool["pool_pos"]]
    return decode_cache_nbytes(leaves)


class PoolExhausted(RuntimeError):
    """Raised by ``BlockManager.alloc`` when the pool cannot satisfy an
    allocation even after reclaiming cached blocks — the server's cue to
    preempt (or, at admission, to keep the request queued)."""


class BlockManager:
    """Host-side allocator for the paged KV pool.

    * **Free-list allocation** — O(1) alloc/free of fixed-size physical
      blocks; every live block has refcount ≥ 1.
    * **Prefix caching** — finished prefills register their full prompt
      blocks under a chain hash (``h_i = hash((h_{i-1}, tokens of block
      i))``); a later request whose prompt starts with the same token
      blocks shares the physical blocks (refcount++), skipping both the
      HBM and the prefill compute for the shared prefix.  The registry
      holds one reference per cached block, so cached blocks survive
      their writer's release and are reclaimed LRU only under pool
      pressure.  Shared blocks are never written: the engine starts
      chunked prefill at the shared boundary and decode writes land past
      the prompt, which is what makes block-granular sharing safe
      without copy-on-write copies (docs/paged_kv.md).
    * **Accounting** — ``live_blocks``/``free_blocks`` and hit/reclaim
      counters feed the serve-bench pool-utilization report.

    The device never sees this object: it only materializes as the
    (slots, n_blocks) int32 block-table operand the kernels' index maps
    read.
    """

    def __init__(self, num_blocks: int, block_size: int, *,
                 prefix_cache: bool = True):
        assert num_blocks > 0 and block_size > 0
        self.num_blocks = int(num_blocks)
        self.block_size = int(block_size)
        self.prefix_cache = prefix_cache
        self.refcount = np.zeros(self.num_blocks, np.int32)
        self._free: deque = deque(range(self.num_blocks))
        self._cached: "OrderedDict[bytes, int]" = OrderedDict()  # digest→blk
        self._hash_of: Dict[int, bytes] = {}                     # blk→digest
        self.stats: Dict[str, int] = {
            "allocated": 0, "freed": 0, "reclaimed": 0,
            "prefix_queries": 0, "prefix_hit_blocks": 0,
        }

    # -- accounting -----------------------------------------------------
    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def live_blocks(self) -> int:
        """Blocks referenced by at least one slot or the prefix cache."""
        return self.num_blocks - len(self._free)

    def _reclaimable(self) -> int:
        return sum(1 for b in self._cached.values()
                   if self.refcount[b] == 1)

    def can_alloc(self, n: int) -> bool:
        return self.free_blocks + self._reclaimable() >= n

    # -- alloc / free ---------------------------------------------------
    def alloc(self, n: int) -> List[int]:
        """Take ``n`` blocks (refcount 1 each); reclaims LRU cached
        blocks under pressure; raises ``PoolExhausted`` if the pool
        genuinely cannot cover the request."""
        if n == 0:
            return []
        while self.free_blocks < n and self._reclaim_one():
            pass
        if self.free_blocks < n:
            raise PoolExhausted(
                f"need {n} KV blocks, {self.free_blocks} free of "
                f"{self.num_blocks} (live {self.live_blocks})")
        out = [self._free.popleft() for _ in range(n)]
        for b in out:
            self.refcount[b] = 1
        self.stats["allocated"] += n
        return out

    def free(self, blocks: Sequence[int]) -> None:
        """Drop one reference per block; a block returns to the free
        list when nothing references it (prefix-cache entries hold their
        own reference, so cached blocks survive their writer)."""
        for b in blocks:
            assert self.refcount[b] > 0, f"double free of block {b}"
            self.refcount[b] -= 1
            if self.refcount[b] == 0:
                self._free.append(b)
                self.stats["freed"] += 1

    def _reclaim_one(self) -> bool:
        for h, b in self._cached.items():
            if self.refcount[b] == 1:       # only the cache holds it
                del self._cached[h]
                del self._hash_of[b]
                self.refcount[b] = 0
                self._free.append(b)
                self.stats["reclaimed"] += 1
                return True
        return False

    # -- prefix caching -------------------------------------------------
    def block_hashes(self, tokens: np.ndarray) -> List[bytes]:
        """Chain digests of the token blocks fully covered by ``tokens``
        — ``h_i`` commits to the whole prefix through block ``i``, so a
        single-digest match implies the entire chain matches.  SHA-256
        over (parent digest ‖ canonical int64 token bytes): a match IS
        the content check — Python's randomized 64-bit ``hash()`` would
        make a silent cross-request KV collision merely improbable and
        unreproducible, not impossible."""
        bs = self.block_size
        h = b""
        out: List[bytes] = []
        toks = np.asarray(tokens, np.int64)
        for i in range(len(toks) // bs):
            h = hashlib.sha256(h + toks[i * bs:(i + 1) * bs].tobytes()) \
                .digest()
            out.append(h)
        return out

    def match_prefix(self, tokens: np.ndarray) -> List[int]:
        """Longest cached chain matching the prompt's leading full
        blocks, **capped at len(tokens) − 1** (the last prompt token
        must be recomputed — its logits seed generation).  Matched
        blocks come back refcounted for the caller; a caller that ends
        up not using some or all of them must hand those back through
        ``unmatch`` so references AND hit accounting stay exact."""
        self.stats["prefix_queries"] += 1
        if not self.prefix_cache:
            return []
        usable = (len(tokens) - 1) // self.block_size
        out: List[int] = []
        for h in self.block_hashes(tokens)[:usable]:
            b = self._cached.get(h)
            if b is None:
                break
            out.append(b)
            self._cached.move_to_end(h)     # LRU touch
        for b in out:
            self.refcount[b] += 1
        self.stats["prefix_hit_blocks"] += len(out)
        return out

    def unmatch(self, blocks: Sequence[int], *,
                whole_query: bool = False) -> None:
        """Exactly reverse (part of) a ``match_prefix`` the caller did
        not use: drop the references and the hit accounting, and with
        ``whole_query`` the query count too (the match never led to an
        admission).  Keeps the stat/refcount invariant inside the
        manager instead of making callers hand-reverse counters."""
        self.free(blocks)
        self.stats["prefix_hit_blocks"] -= len(blocks)
        if whole_query:
            self.stats["prefix_queries"] -= 1

    def registry_size(self) -> int:
        """Number of cached prefix blocks — with ``free_blocks``/
        ``live_blocks`` this fingerprints every state a repeated
        ``match_prefix`` could answer differently from."""
        return len(self._cached)

    def register_prefix(self, tokens: np.ndarray,
                        blocks: Sequence[int]) -> None:
        """Publish a *fully prefilled* prompt's full blocks to the
        prefix cache (one cache reference each).  Must only be called
        once the blocks' contents are final — the engine calls it when a
        prefill completes, never mid-flight, so a shared block can never
        be half-written."""
        if not self.prefix_cache:
            return
        for h, b in zip(self.block_hashes(tokens), blocks):
            if h in self._cached or b in self._hash_of:
                continue                     # first writer wins
            self._cached[h] = b
            self._hash_of[b] = h
            self.refcount[b] += 1
