"""KV-cache utilities: sizing arithmetic + the slot API the continuous-
batching engine is built on.

Cache construction lives with the model (transformer._cache_from_prefill)
so layouts stay next to the attention code; this module adds the
serving-side pieces:

* ``kv_cache_bytes``        — footprint arithmetic (estimator/server).
* ``alloc_decode_cache``    — zero-filled slot-addressed decode cache of
                              ``slots`` rows × ``capacity`` KV entries,
                              position arrays initialised to -1 (invalid).
* ``slot_batch_axes`` / ``take_slot`` / ``put_slot`` — the slot-view API
  chunked pad-free prefill is built on: slice one slot's row out of the
  big cache (a batch-1 sub-cache), run a prefill chunk against it, and
  splice it back.  Admission resets a slot by ``put_slot``-ing an empty
  batch-1 cache in (positions −1, SSM state zeroed).
* ``release_slot``          — invalidate a slot row's positions so stale
                              KV can never be attended (the free path).
* ``abstract_decode_cache`` — ShapeDtypeStructs of the above, for AOT
                              export (eon_compiler.compile_serve_decode).

Validity is decided by stored positions (−1 = empty) plus the
scheduler's per-slot ``kv_len`` bound, so a slot row can be recycled
between decode steps without touching the K/V bytes.

Every entry point is precision-aware (``PrecisionPolicy``): an int8
policy makes the KV leaves ``Int8KV`` pairs — int8 values plus one f32
scale per (entry, head) — and the slot API splices/releases/sizes the
paired pytree; ``decode_cache_nbytes`` measures the HBM delta.
"""
from __future__ import annotations

from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.arch import ArchConfig, ShapeConfig
from repro.core.quantize import PrecisionPolicy
from repro.models.transformer import grow_cache  # noqa: F401  (re-export)


def kv_cache_bytes(cfg: ArchConfig, batch: int, seq_len: int,
                   dtype_bytes: int = 2, *,
                   precision: str = "float") -> int:
    """Global KV/state cache footprint for one decode session.

    ``precision="int8"`` prices the Int8KV layout: 1 byte per value plus
    one f32 scale per (entry, head) vector of ``head_dim`` values —
    attention KV only; SSM recurrent state stays float either way.
    """
    hd = cfg.resolved_head_dim
    # bytes per stored attention-KV scalar; the int8 layout adds one f32
    # scale per head-vector of hd values.  SSM conv/recurrent state stays
    # float under every precision.
    kv_bytes = (hd + 4) / hd if precision == "int8" else dtype_bytes
    if cfg.family == "ssm":
        conv = batch * (cfg.d_conv - 1) * cfg.d_inner * dtype_bytes
        h = batch * cfg.d_inner * cfg.ssm_state * 4
        return int(cfg.n_layers * (conv + h))
    if cfg.family == "hybrid":
        nh = cfg.resolved_ssm_heads
        hp = cfg.d_inner // nh
        conv = batch * (cfg.d_conv - 1) * cfg.d_inner * dtype_bytes
        h = batch * nh * hp * cfg.ssm_state * 4
        n_attn = cfg.n_layers // max(cfg.attn_every, 1)
        kv = n_attn * 2 * batch * seq_len * cfg.n_kv_heads * hd * kv_bytes
        return int(cfg.n_layers * (conv + h) + kv)
    per_layer_kv = 2 * batch * cfg.n_kv_heads * hd * kv_bytes
    if cfg.sliding_window and cfg.local_global_ratio:
        r = cfg.local_global_ratio
        n_global = cfg.n_layers // (r + 1)
        n_local = cfg.n_layers - n_global
        return int(n_global * per_layer_kv * seq_len
                   + n_local * per_layer_kv * min(cfg.sliding_window, seq_len))
    # Enc-dec: encoder layers hold no decode-time cache (the encoder runs
    # once; its output *is* the cross KV).  The decoder holds self-attn KV
    # over seq_len plus cross-attn KV over the subsampled encoder length.
    total = cfg.n_layers * per_layer_kv * seq_len
    if cfg.is_encdec:
        total += cfg.n_layers * per_layer_kv * (seq_len // cfg.enc_seq_divisor)
    return int(total)


# ---------------------------------------------------------------------------
# Slot-addressed decode cache (continuous batching)
# ---------------------------------------------------------------------------
def abstract_decode_cache(cfg: ArchConfig, slots: int, capacity: int,
                          policy: Optional[PrecisionPolicy] = None):
    """ShapeDtypeStructs of a ``slots`` × ``capacity`` decode cache.
    With an int8 ``policy`` the KV leaves come back as Int8KV pairs."""
    from repro.models.api import abstract_cache
    shape = ShapeConfig("serve_alloc", seq_len=capacity, global_batch=slots,
                        kind="prefill")
    return abstract_cache(cfg, shape, policy)


def decode_cache_nbytes(cache) -> int:
    """HBM footprint of a (concrete or abstract) decode-cache pytree —
    every leaf: KV values, Int8KV scales, position bookkeeping."""
    return sum(int(np.prod(leaf.shape)) * jnp.dtype(leaf.dtype).itemsize
               for leaf in jax.tree.leaves(cache))


def alloc_decode_cache(cfg: ArchConfig, slots: int, capacity: int,
                       policy: Optional[PrecisionPolicy] = None):
    """Concrete all-empty decode cache: zeros, positions −1 (invalid)."""
    abs_cache = abstract_decode_cache(cfg, slots, capacity, policy)

    def init(key_path, sds):
        name = key_path[0].key if hasattr(key_path[0], "key") else None
        if name is not None and name.endswith("_pos"):
            return jnp.full(sds.shape, -1, sds.dtype)
        return jnp.zeros(sds.shape, sds.dtype)

    return jax.tree_util.tree_map_with_path(init, abs_cache)


def _first_diff_axis(big_shape, small_shape) -> int:
    """Axis where a batch-1 sub-cache differs from the full cache (the
    batch axis — it always precedes any seq-length difference)."""
    for i, (b, s) in enumerate(zip(big_shape, small_shape)):
        if b != s:
            return i
    return -1  # identical shapes: slots == 1, write in place


def slot_batch_axes(cfg: ArchConfig, slots: int, capacity: int,
                    policy: Optional[PrecisionPolicy] = None):
    """Per-leaf batch-axis pytree of the decode cache, inferred by
    diffing the ``slots``-row abstract cache against its batch-1 twin —
    robust to every layout (stacked-layer KV, Int8KV value/scale pairs,
    nested SSM state).  Computed once per server; closed over (static)
    by the jitted slot-view steps.  −1 marks a leaf with no batch axis
    (only possible when ``slots == 1``: slice/splice in place)."""
    big = abstract_decode_cache(cfg, slots, capacity, policy)
    small = abstract_decode_cache(cfg, 1, capacity, policy)
    return jax.tree.map(lambda b, s: _first_diff_axis(b.shape, s.shape),
                        big, small)


def take_slot(big_cache, axes, slot):
    """Slice slot ``slot``'s row out of the big cache as a batch-1
    sub-cache (``axes`` from ``slot_batch_axes``, closed over — the axis
    choice must be static under jit; ``slot`` may be traced)."""
    def take(big, axis):
        if axis < 0:
            return big
        starts = [0] * big.ndim
        starts[axis] = slot
        sizes = list(big.shape)
        sizes[axis] = 1
        return lax.dynamic_slice(big, tuple(starts), tuple(sizes))
    return jax.tree.map(take, big_cache, axes)


def put_slot(big_cache, small_cache, axes, slot):
    """Splice a batch-1 sub-cache back into row ``slot`` — the inverse
    of ``take_slot``.  Splicing a fresh ``alloc_decode_cache(cfg, 1, …)``
    resets the slot (positions −1, SSM state zeroed) for admission."""
    def put(big, small, axis):
        starts = [0] * big.ndim
        if axis >= 0:
            starts[axis] = slot
        return lax.dynamic_update_slice(big, small.astype(big.dtype),
                                        tuple(starts))
    return jax.tree.map(put, big_cache, small_cache, axes)


def release_slot(big_cache: Dict[str, Any], slot) -> Dict[str, Any]:
    """Invalidate a slot row: set its position entries to −1.  K/V bytes
    stay in place — they are unreachable once no position marks them."""
    out = dict(big_cache)
    for key, big in big_cache.items():
        if key.endswith("_pos"):
            row = jnp.full((1, big.shape[1]), -1, big.dtype)
            out[key] = lax.dynamic_update_slice(big, row, (slot, 0))
    return out
