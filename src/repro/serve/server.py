"""Batched serving loop — the "EIM process runner" analogue (paper §4.6):
a deployed artifact behind a queue-driven I/O interface.

Requests join a waiting queue; the scheduler forms prefill batches
(padded to the compiled bucket), then all active sequences advance
through shared decode steps (continuous batching at step granularity:
finished sequences free their slot for waiting requests between steps).
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arch import ArchConfig
from repro.models import api
from repro.models.transformer import grow_cache
from repro.serve.serve_step import make_decode_step, make_prefill_step


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    submitted_at: float = 0.0
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None


class BatchServer:
    """Greedy-decoding batch server over the framework's serve steps."""

    def __init__(self, cfg: ArchConfig, params, *, batch_size: int = 4,
                 prompt_len: int = 32, max_new_tokens: int = 16):
        self.cfg = cfg
        self.params = params
        self.batch_size = batch_size
        self.prompt_len = prompt_len
        self.max_new = max_new_tokens
        self.prefill = jax.jit(make_prefill_step(cfg))
        self.decode = jax.jit(make_decode_step(cfg))
        self.queue: deque[Request] = deque()
        self.metrics: Dict[str, float] = {}

    def submit(self, prompts: List[np.ndarray],
               max_new_tokens: Optional[int] = None) -> List[Request]:
        reqs = []
        for i, p in enumerate(prompts):
            r = Request(rid=len(self.queue) + i, prompt=p,
                        max_new_tokens=max_new_tokens or self.max_new,
                        submitted_at=time.perf_counter())
            self.queue.append(r)
            reqs.append(r)
        return reqs

    def _pad_batch(self, reqs: List[Request]) -> np.ndarray:
        out = np.zeros((self.batch_size, self.prompt_len), np.int32)
        for i, r in enumerate(reqs):
            p = r.prompt[-self.prompt_len:]
            out[i, -len(p):] = p       # left-pad into the fixed bucket
        return out

    def run(self) -> Dict[str, float]:
        """Serve until the queue drains; returns latency metrics."""
        t_start = time.perf_counter()
        served: List[Request] = []
        total_decode_steps = 0
        while self.queue:
            batch = [self.queue.popleft()
                     for _ in range(min(self.batch_size, len(self.queue)))]
            tokens = jnp.asarray(self._pad_batch(batch))
            next_tok, logits, cache = self.prefill(self.params,
                                                   {"tokens": tokens})
            cache = grow_cache(self.cfg, cache, self.max_new + 1)
            now = time.perf_counter()
            ntok = np.asarray(next_tok)
            for i, r in enumerate(batch):
                r.tokens.append(int(ntok[i]))
                r.first_token_at = now
            pos = jnp.full((self.batch_size,), self.prompt_len, jnp.int32)
            cur = next_tok
            for step in range(self.max_new - 1):
                cur, logits, cache = self.decode(self.params, cache, cur,
                                                 pos + step)
                total_decode_steps += 1
                ctok = np.asarray(cur)
                for i, r in enumerate(batch):
                    if not r.done:
                        r.tokens.append(int(ctok[i]))
                        if len(r.tokens) >= r.max_new_tokens:
                            r.done = True
                            r.finished_at = time.perf_counter()
            for r in batch:
                r.done = True
                r.finished_at = r.finished_at or time.perf_counter()
            served.extend(batch)

        wall = time.perf_counter() - t_start
        ttfts = [r.first_token_at - r.submitted_at for r in served]
        gen_tokens = sum(len(r.tokens) for r in served)
        self.metrics = {
            "requests": len(served),
            "wall_s": wall,
            "ttft_mean_s": float(np.mean(ttfts)),
            "tokens_generated": gen_tokens,
            "tokens_per_s": gen_tokens / max(wall, 1e-9),
            "decode_steps": total_decode_steps,
        }
        return self.metrics
