"""Serving engines — the "EIM process runner" analogue (paper §4.6):
a deployed artifact behind a queue-driven I/O interface.

Two schedulers over the same model serve steps:

* ``ContinuousBatchServer`` (the default ``BatchServer``) — slot-based
  continuous batching with **chunked pad-free prefill**: a prompt of
  length S is consumed in ceil(S / C) fixed-size chunk steps interleaved
  with decode under a per-step token budget, each chunk written unpadded
  into the slot's cache rows ``[p, p + C)``.  Finished sequences release
  their KV-cache slot *between decode steps* and waiting requests are
  admitted into freed slots; per-request ``max_new_tokens`` is honored
  in-step.  One chunk shape compiles once (instead of one shape per
  padded bucket); optionally the decode hot loop runs a
  ``CompiledArtifact`` (``core/eon_compiler.compile_serve_decode``) so
  serving executes the same AOT executable we "deploy" (paper C4).
* ``StaticBatchServer`` — the classic baseline: a batch is formed once,
  prefilled to completion (same pad-free chunk steps, no interleaving),
  and decodes until its slowest member finishes; short requests block
  behind long ones.  Kept as the benchmark control.

Both engines accept ``precision="float" | "int8"`` (paper C5 threaded
end-to-end): int8 wraps projection weights in QTensor once at
construction, serves through the quant-aware matmul entry point, and
keeps the decode cache as Int8KV — ≥2× KV HBM, token-exact against the
fake-quant float reference (docs/quantization.md).

Both feed the decode step a per-slot ``kv_len`` — with pad-free
admission this is the *exact* live fill (``position + 1``; 0 for idle or
mid-prefill slots, whose rows the step neither reads nor writes) — so
the flash-decode kernel reads only each slot's live prefix of the
capacity rectangle, and int8 decode dequantizes inside the kernel tile,
never materializing a float cache (docs/serving.md, "Flash-decode
kernel").

No pad row ever enters the KV cache or an SSM recurrence, so batched
serving is token-exact versus an unpadded single-request decode for
every supported architecture family — attention, sliding-window ring,
and SSM/hybrid alike (docs/scheduling.md).  Prompts that cannot fit a
slot's capacity are rejected at ``submit`` with an explicit error;
nothing is silently truncated.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arch import ArchConfig
from repro.core.quantize import policy_for, quantize_model_params
from repro.serve.kvcache import (alloc_decode_cache, decode_cache_nbytes,
                                 put_slot, release_slot, slot_batch_axes)
from repro.serve.scheduler import SlotScheduler
from repro.serve.serve_step import (make_chunk_prefill_step,
                                    make_slot_decode_step)

# Decode-cache capacity granularity: one flash-decode KV block (a
# sub-multiple of kernels/flash_decode.py's block_k, so any rounded
# capacity tiles cleanly on every backend).
KV_BLOCK = 64


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    submitted_at: float = 0.0
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    admitted_step: Optional[int] = None   # decode-step clock at admission
    finished_step: Optional[int] = None


def _check_supported(cfg: ArchConfig) -> None:
    if cfg.is_encdec or cfg.frontend:
        raise NotImplementedError(
            f"{cfg.name}: serving engine requires a token-input decoder-only"
            " architecture (enc-dec / embedding-frontend archs need a"
            " modality runner in front)")


def _chunk_rows(prompt_len: int, chunk: int) -> int:
    """Cache rows a chunked prefill touches: whole chunks, so the ragged
    final chunk's pad tail (written invalid, overwritten by decode)
    still needs rows up to the chunk boundary."""
    return -(-prompt_len // chunk) * chunk


def _summarize(served: List[Request], wall: float, *, engine: str,
               decode_steps: int, prefills: int,
               occupancy: Optional[List[int]] = None,
               n_slots: int = 0) -> Dict[str, float]:
    ttfts = np.array([r.first_token_at - r.submitted_at for r in served])
    gen = sum(len(r.tokens) for r in served)
    m: Dict[str, float] = {
        "engine": engine,
        "requests": len(served),
        "wall_s": wall,
        "ttft_mean_s": float(ttfts.mean()) if len(ttfts) else 0.0,
        "ttft_p50_s": float(np.percentile(ttfts, 50)) if len(ttfts) else 0.0,
        "ttft_p95_s": float(np.percentile(ttfts, 95)) if len(ttfts) else 0.0,
        "tokens_generated": gen,
        "tokens_per_s": gen / max(wall, 1e-9),
        "decode_steps": decode_steps,
        "prefill_chunks": prefills,
    }
    if occupancy and n_slots:
        m["mean_active_slots"] = float(np.mean(occupancy))
        m["slot_utilization"] = float(np.mean(occupancy)) / n_slots
    return m


class _ServerBase:
    def __init__(self, cfg: ArchConfig, params, precision: str = "float"):
        _check_supported(cfg)
        self.cfg = cfg
        self.precision = precision
        self.prec = policy_for(precision)
        # int8: projection weights become QTensor leaves once, up front —
        # the serving hot loop never sees a float weight again.
        self.params = quantize_model_params(params, self.prec)
        self._next_rid = 0
        self.requests: Dict[int, Request] = {}
        self.metrics: Dict[str, float] = {}

    def _slot_capacity(self) -> int:
        """Per-slot KV rows: prompt + generation budget, with headroom
        for a ragged final chunk's pad tail at max_prompt, rounded up to
        the flash-decode KV block so the kernel never pads the cache per
        step; the tail is dead capacity the per-slot kv_len bound skips
        without reading.  Both engines and ``_check_fits`` share this."""
        need = max(self.max_prompt + self.max_new_cap,
                   _chunk_rows(self.max_prompt, self.chunk))
        return -(-need // KV_BLOCK) * KV_BLOCK

    def _init_slot_steps(self, n_slots: int) -> None:
        """Chunk-prefill / decode / reset steps over an ``n_slots`` ×
        ``self.capacity`` cache (shared by both engines)."""
        axes = slot_batch_axes(self.cfg, n_slots, self.capacity, self.prec)
        # the cache is dead after every call (immediately reassigned):
        # donate it so steps update rows in place instead of copying the
        # whole KV allocation per token
        self._chunk_step = jax.jit(
            make_chunk_prefill_step(self.cfg, axes=axes, policy=self.prec),
            donate_argnums=(1,))
        self._reset = jax.jit(
            lambda cache, empty, slot: put_slot(cache, empty, axes, slot),
            donate_argnums=(0,))
        self._release = jax.jit(release_slot, donate_argnums=(0,))
        self._empty_row = alloc_decode_cache(self.cfg, 1, self.capacity,
                                             self.prec)
        self.cache = alloc_decode_cache(self.cfg, n_slots, self.capacity,
                                        self.prec)
        # host mirror of the last emitted token per slot (decode feed)
        self._cur = np.zeros((n_slots,), np.int32)

    def _check_fits(self, prompt: np.ndarray, max_new: int) -> None:
        """Explicit capacity check at submit — any prompt that fits is
        served exactly; anything else errors instead of being silently
        truncated (the old bucket policy's failure mode)."""
        s = len(prompt)
        if s < 1:
            raise ValueError("empty prompt")
        need = max(s + max_new, _chunk_rows(s, self.chunk))
        if need > self.capacity:
            raise ValueError(
                f"prompt of {s} tokens + {max_new} new needs {need} cache"
                f" rows > slot capacity {self.capacity}; raise max_prompt/"
                f"max_new_cap (or shorten the prompt)")

    def _make_requests(self, prompts: List[np.ndarray],
                       max_new_tokens) -> List[Request]:
        if max_new_tokens is None:
            max_new_tokens = self.max_new
        if isinstance(max_new_tokens, int):
            max_new_tokens = [max_new_tokens] * len(prompts)
        assert len(max_new_tokens) == len(prompts)
        # validate the whole batch before registering anything, so a
        # rejected prompt leaves no orphaned half-submitted requests
        checked = []
        for p, mn in zip(prompts, max_new_tokens):
            p = np.asarray(p, np.int32)
            mn = max(1, min(int(mn), self.max_new_cap))
            self._check_fits(p, mn)
            checked.append((p, mn))
        now = time.perf_counter()
        reqs = []
        for p, mn in checked:
            r = Request(rid=self._next_rid, prompt=p, max_new_tokens=mn,
                        submitted_at=now)
            self._next_rid += 1
            self.requests[r.rid] = r
            reqs.append(r)
        return reqs

    def _run_chunk(self, slot, step_clock: int) -> None:
        """One prefill chunk for ``slot``; flips it ACTIVE (and emits the
        first token) when the prompt is exhausted."""
        c = self.chunk
        prompt = slot.prompt
        p = slot.chunk_pos
        r = min(c, len(prompt) - p)
        toks = np.zeros((1, c), np.int32)
        poss = np.full((1, c), -1, np.int32)
        toks[0, :r] = prompt[p:p + r]
        poss[0, :r] = np.arange(p, p + r, dtype=np.int32)
        kvl = jnp.asarray([p + c], jnp.int32)
        ntok, _, self.cache = self._chunk_step(
            self.params, self.cache, jnp.asarray(toks), jnp.asarray(poss),
            slot.index, kvl)
        slot.chunk_pos += r
        if slot.chunk_pos < len(prompt):
            return
        # final chunk: its last real row's logits are the first token
        req = self.requests[slot.rid]
        tok0 = int(np.asarray(ntok)[0, r - 1])
        req.tokens.append(tok0)
        req.first_token_at = time.perf_counter()
        slot.begin_decode()
        if req.max_new_tokens <= 1 or tok0 == self.eos_id:
            self._finish(req, step_clock)
            self.cache = self._release(self.cache, slot.index)
            slot.release()
        else:
            self._cur[slot.index] = tok0

    def _finish(self, req: Request, step_clock: int) -> None:
        req.done = True
        req.finished_at = time.perf_counter()
        req.finished_step = step_clock
        self._served.append(req)


class ContinuousBatchServer(_ServerBase):
    """Continuous batching: slot recycling between decode steps, with
    prefill chunks scheduled *inside* the decode loop.

    ``slots`` decode rows share one jitted decode step; prompts are
    consumed ``prefill_chunk`` tokens at a time (one compiled chunk
    shape, pad-free cache rows) under ``prefill_token_budget`` prefill
    tokens per decode step, so a long prompt cannot head-of-line-block
    the active slots' next tokens.  ``batch_size`` / ``prompt_len`` are
    accepted as aliases so existing callers keep working.
    """

    def __init__(self, cfg: ArchConfig, params, *,
                 slots: Optional[int] = None,
                 max_prompt: Optional[int] = None,
                 prefill_chunk: int = 8,
                 prefill_token_budget: Optional[int] = None,
                 max_new_tokens: int = 16,
                 max_new_cap: Optional[int] = None,
                 eos_id: Optional[int] = None,
                 use_artifact: bool = False,
                 batch_size: Optional[int] = None,
                 prompt_len: Optional[int] = None,
                 precision: str = "float"):
        super().__init__(cfg, params, precision)
        self.n_slots = int(slots or batch_size or 4)
        self.max_prompt = int(max_prompt or prompt_len or 32)
        self.chunk = int(prefill_chunk)
        # fairness knob: prefill tokens spent per decode step once any
        # slot is actively decoding (floored at one chunk so admission
        # always progresses); see docs/scheduling.md for the trade-off.
        self.prefill_budget = int(prefill_token_budget or self.chunk)
        self.max_new = int(max_new_tokens)
        self.max_new_cap = int(max_new_cap or max(self.max_new, 1))
        self.capacity = self._slot_capacity()
        # effective flash-decode block at this capacity (mirrors the
        # kernel's choice: min(128, S), halved until it divides S) —
        # the HBM-read metric quantizes to it
        bk = min(128, self.capacity)
        while self.capacity % bk and bk > 8:
            bk //= 2
        self._kv_block = bk
        self.eos_id = eos_id
        self.sched = SlotScheduler(self.n_slots)
        self._init_slot_steps(self.n_slots)
        self.artifact = None
        if use_artifact:
            from repro.core.eon_compiler import compile_serve_decode
            self.artifact = compile_serve_decode(
                cfg, self.params, slots=self.n_slots, capacity=self.capacity,
                policy=self.prec)
            self.decode = self.artifact.rehydrate()
        else:
            self.decode = jax.jit(
                make_slot_decode_step(cfg, policy=self.prec),
                donate_argnums=(1,))

    # ------------------------------------------------------------------
    def submit(self, prompts: List[np.ndarray],
               max_new_tokens: Union[int, Sequence[int], None] = None
               ) -> List[Request]:
        reqs = self._make_requests(prompts, max_new_tokens)
        for r in reqs:
            self.sched.enqueue(r)
        return reqs

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, float]:
        """Serve until queue and slots drain; returns latency metrics."""
        t0 = time.perf_counter()
        self._served: List[Request] = []
        decode_steps = 0
        prefill_chunks = 0
        occupancy: List[int] = []
        kv_fill: List[int] = []   # Σ block-rounded kv_len per decode step
        kv_raw: List[int] = []    # Σ kv_len per decode step (exact fill)

        while self.sched.busy:
            # Admission: freed slots pick up waiting requests *now*, not
            # at the end of a batch — the continuous-batching invariant.
            # One slot-row reset on device; the prefill compute itself
            # is chunked below.
            for slot, req in self.sched.admissions():
                self.cache = self._reset(self.cache, self._empty_row,
                                         slot.index)
                slot.occupy(req.rid, req.prompt, req.max_new_tokens)
                req.admitted_step = decode_steps

            # Budgeted chunk prefill, oldest request first: at most
            # prefill_budget prompt tokens per decode step (always at
            # least one chunk), so active slots keep emitting while long
            # prompts stream in.
            spent = 0
            for slot in sorted(self.sched.prefilling_slots(),
                               key=lambda s: s.rid):
                while slot.prefilling and spent < self.prefill_budget:
                    self._run_chunk(slot, decode_steps)
                    prefill_chunks += 1
                    spent += self.chunk
                if spent >= self.prefill_budget:
                    break

            active = self.sched.active_slots()
            if not active:
                continue

            tok = np.array(self._cur)
            pos = np.zeros((self.n_slots,), np.int32)
            # per-slot fill: pad-free, so fill == position + 1 exactly
            # (0 = idle or mid-prefill slot: skipped outright, and the
            # step suppresses its writes)
            kvl = np.zeros((self.n_slots,), np.int32)
            for s in active:
                pos[s.index] = s.position
                kvl[s.index] = s.position + 1
            ntok, _, self.cache = self.decode(self.params, self.cache,
                                              tok, pos, kvl)
            decode_steps += 1
            occupancy.append(len(active))
            # block-granular: the kernel fetches whole KV blocks, and
            # even an idle slot's clamped index map fetches one
            blocks = np.maximum(-(-kvl // self._kv_block), 1)
            kv_fill.append(int(blocks.sum()) * self._kv_block)
            kv_raw.append(int(kvl.sum()))
            ntok_h = np.asarray(ntok)

            for s in active:
                req = self.requests[s.rid]
                t = int(ntok_h[s.index])
                req.tokens.append(t)
                s.advance()
                self._cur[s.index] = t
                if s.generated >= s.max_new or t == self.eos_id:
                    self._finish(req, decode_steps)
                    self.cache = self._release(self.cache, s.index)
                    s.release()

        served = self._served
        wall = time.perf_counter() - t0
        self.metrics = _summarize(served, wall, engine="continuous",
                                  decode_steps=decode_steps,
                                  prefills=prefill_chunks,
                                  occupancy=occupancy,
                                  n_slots=self.n_slots)
        self.metrics["precision"] = self.precision
        self.metrics["prefill_chunk"] = self.chunk
        self.metrics["kv_cache_bytes"] = decode_cache_nbytes(self.cache)
        if kv_fill:
            # fraction of the slots × capacity rectangle the bounded
            # decode kernel reads per step (1.0 = no bounding).  Block-
            # granular at the kernel's effective block, and exact only
            # for the kv_len-bounded full-attention leaves — ring/local
            # caches carry their own position-based bound.
            # kv_fill_frac is the exact live fill (entries) — pad-free,
            # so it counts only real prompt/generated tokens — the floor
            # the read fraction approaches as capacity / block grows.
            denom = self.n_slots * self.capacity
            self.metrics["kv_read_frac"] = float(np.mean(kv_fill) / denom)
            self.metrics["kv_fill_frac"] = float(np.mean(kv_raw) / denom)
        if self.artifact is not None:
            self.metrics["artifact_bytes"] = self.artifact.artifact_bytes
        return self.metrics


class StaticBatchServer(_ServerBase):
    """Static batching baseline: the queue is drained in fixed batches
    and every batch decodes until its *slowest* member finishes — slots
    are never recycled mid-flight.  Prefill uses the same pad-free chunk
    steps as the continuous engine (run to completion up front, no
    interleaving), so token-for-token the two engines match on every
    architecture family; only scheduling differs.
    """

    def __init__(self, cfg: ArchConfig, params, *, batch_size: int = 4,
                 max_prompt: Optional[int] = None,
                 prefill_chunk: int = 8,
                 prompt_len: Optional[int] = None,
                 max_new_tokens: int = 16,
                 precision: str = "float"):
        super().__init__(cfg, params, precision)
        self.batch_size = int(batch_size)
        self.max_prompt = int(max_prompt or prompt_len or 32)
        self.chunk = int(prefill_chunk)
        self.max_new = int(max_new_tokens)
        self.max_new_cap = self.max_new
        self.eos_id = None
        self.capacity = self._slot_capacity()
        self.queue: List[Request] = []
        self._init_slot_steps(self.batch_size)
        self.decode = jax.jit(
            make_slot_decode_step(cfg, policy=self.prec),
            donate_argnums=(1,))

    def submit(self, prompts: List[np.ndarray],
               max_new_tokens: Union[int, Sequence[int], None] = None
               ) -> List[Request]:
        reqs = self._make_requests(prompts, max_new_tokens)
        self.queue.extend(reqs)
        return reqs

    def run(self) -> Dict[str, float]:
        from repro.serve.scheduler import Slot
        t0 = time.perf_counter()
        self._served: List[Request] = []
        decode_steps = 0
        prefill_chunks = 0
        while self.queue:
            batch = self.queue[:self.batch_size]
            self.queue = self.queue[self.batch_size:]
            slots = []
            for i, r in enumerate(batch):
                self.cache = self._reset(self.cache, self._empty_row, i)
                slot = Slot(i)
                slot.occupy(r.rid, r.prompt, r.max_new_tokens)
                r.admitted_step = decode_steps
                while slot.prefilling:      # full prefill, no interleave
                    self._run_chunk(slot, decode_steps)
                    prefill_chunks += 1
                slots.append(slot)
            horizon = max(r.max_new_tokens for r in batch) - 1
            # the batch decodes as one unit until its slowest member
            # drains; finished rows keep stepping (outputs discarded)
            for _ in range(horizon):
                if not any(s.active for s in slots):
                    break
                tok = np.array(self._cur)
                pos = np.zeros((self.batch_size,), np.int32)
                kvl = np.zeros((self.batch_size,), np.int32)
                for s in slots:
                    if s.active:
                        pos[s.index] = s.position
                        kvl[s.index] = s.position + 1
                ntok, _, self.cache = self.decode(self.params, self.cache,
                                                  tok, pos, kvl)
                decode_steps += 1
                ntok_h = np.asarray(ntok)
                for s in slots:
                    if not s.active:
                        continue
                    r = self.requests[s.rid]
                    t = int(ntok_h[s.index])
                    s.advance()
                    self._cur[s.index] = t
                    if not r.done:
                        r.tokens.append(t)
                        if len(r.tokens) >= r.max_new_tokens:
                            self._finish(r, decode_steps)

        served = self._served
        wall = time.perf_counter() - t0
        self.metrics = _summarize(served, wall, engine="static",
                                  decode_steps=decode_steps,
                                  prefills=prefill_chunks)
        self.metrics["precision"] = self.precision
        self.metrics["prefill_chunk"] = self.chunk
        self.metrics["kv_cache_bytes"] = decode_cache_nbytes(self.cache)
        return self.metrics


# Default engine: continuous batching (what the old name promised).
BatchServer = ContinuousBatchServer
