"""Serving engines — the "EIM process runner" analogue (paper §4.6):
a deployed artifact behind a queue-driven I/O interface.

Three schedulers over the same model serve steps:

* ``PagedBatchServer`` — continuous batching over a **paged KV pool**:
  fixed-size physical KV blocks addressed through per-slot block
  tables, with hash-based prefix sharing and preempt-and-recompute when
  the pool runs dry (docs/paged_kv.md).  Live-token HBM replaces
  worst-case-rectangle HBM; the two rectangle engines below remain the
  measured baselines.

* ``ContinuousBatchServer`` (the default ``BatchServer``) — slot-based
  continuous batching with **chunked pad-free prefill**: a prompt of
  length S is consumed in ceil(S / C) fixed-size chunk steps interleaved
  with decode under a per-step token budget, each chunk written unpadded
  into the slot's cache rows ``[p, p + C)``.  Finished sequences release
  their KV-cache slot *between decode steps* and waiting requests are
  admitted into freed slots; per-request ``max_new_tokens`` is honored
  in-step.  One chunk shape compiles once (instead of one shape per
  padded bucket); optionally the decode hot loop runs a
  ``CompiledArtifact`` (``core/eon_compiler.compile_serve_decode``) so
  serving executes the same AOT executable we "deploy" (paper C4).
* ``StaticBatchServer`` — the classic baseline: a batch is formed once,
  prefilled to completion (same pad-free chunk steps, no interleaving),
  and decodes until its slowest member finishes; short requests block
  behind long ones.  Kept as the benchmark control.

Both engines accept ``precision="float" | "int8"`` (paper C5 threaded
end-to-end): int8 wraps projection weights in QTensor once at
construction, serves through the quant-aware matmul entry point, and
keeps the decode cache as Int8KV — ≥2× KV HBM, token-exact against the
fake-quant float reference (docs/quantization.md).

Both feed the decode step a per-slot ``kv_len`` — with pad-free
admission this is the *exact* live fill (``position + 1``; 0 for idle or
mid-prefill slots, whose rows the step neither reads nor writes) — so
the flash-decode kernel reads only each slot's live prefix of the
capacity rectangle, and int8 decode dequantizes inside the kernel tile,
never materializing a float cache (docs/serving.md, "Flash-decode
kernel").

No pad row ever enters the KV cache or an SSM recurrence, so batched
serving is token-exact versus an unpadded single-request decode for
every supported architecture family — attention, sliding-window ring,
and SSM/hybrid alike (docs/scheduling.md).  Prompts that cannot fit a
slot's capacity are rejected at ``submit`` with an explicit error;
nothing is silently truncated.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arch import ArchConfig
from repro.core.quantize import policy_for, quantize_model_params
from repro.serve.kvcache import (BlockManager, PoolExhausted,
                                 alloc_decode_cache, alloc_paged_cache,
                                 decode_cache_nbytes, kv_block_size,
                                 kv_pool_block_bytes, paged_cache_keys,
                                 paged_slot_axes, put_slot, release_slot,
                                 slot_batch_axes)
from repro.serve.scheduler import SlotScheduler
from repro.serve.serve_step import (make_chunk_prefill_step,
                                    make_paged_chunk_prefill_step,
                                    make_paged_decode_step,
                                    make_slot_decode_step)

# Decode-cache capacity granularity: one flash-decode KV block — the
# kernels' tile choice at any rounded capacity is kv_block_size(), and
# rounding capacity to this keeps that choice at its maximum on every
# backend (kvcache.kv_block_size is the single source of truth).
KV_BLOCK = 64


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    submitted_at: float = 0.0
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    admitted_step: Optional[int] = None   # decode-step clock at admission
    finished_step: Optional[int] = None
    preemptions: int = 0            # paged engine: times evicted/recomputed


def _check_supported(cfg: ArchConfig) -> None:
    if cfg.is_encdec or cfg.frontend:
        raise NotImplementedError(
            f"{cfg.name}: serving engine requires a token-input decoder-only"
            " architecture (enc-dec / embedding-frontend archs need a"
            " modality runner in front)")


def _chunk_rows(prompt_len: int, chunk: int) -> int:
    """Cache rows a chunked prefill touches: whole chunks, so the ragged
    final chunk's pad tail (written invalid, overwritten by decode)
    still needs rows up to the chunk boundary."""
    return -(-prompt_len // chunk) * chunk


def _summarize(served: List[Request], wall: float, *, engine: str,
               decode_steps: int, prefills: int,
               occupancy: Optional[List[int]] = None,
               n_slots: int = 0) -> Dict[str, float]:
    ttfts = np.array([r.first_token_at - r.submitted_at for r in served])
    gen = sum(len(r.tokens) for r in served)
    m: Dict[str, float] = {
        "engine": engine,
        "requests": len(served),
        "wall_s": wall,
        "ttft_mean_s": float(ttfts.mean()) if len(ttfts) else 0.0,
        "ttft_p50_s": float(np.percentile(ttfts, 50)) if len(ttfts) else 0.0,
        "ttft_p95_s": float(np.percentile(ttfts, 95)) if len(ttfts) else 0.0,
        "tokens_generated": gen,
        "tokens_per_s": gen / max(wall, 1e-9),
        "decode_steps": decode_steps,
        "prefill_chunks": prefills,
    }
    if occupancy and n_slots:
        m["mean_active_slots"] = float(np.mean(occupancy))
        m["slot_utilization"] = float(np.mean(occupancy)) / n_slots
    return m


class _ServerBase:
    def __init__(self, cfg: ArchConfig, params, precision: str = "float"):
        _check_supported(cfg)
        self.cfg = cfg
        self.precision = precision
        self.prec = policy_for(precision)
        # int8: projection weights become QTensor leaves once, up front —
        # the serving hot loop never sees a float weight again.
        self.params = quantize_model_params(params, self.prec)
        self._next_rid = 0
        self.requests: Dict[int, Request] = {}
        self.metrics: Dict[str, float] = {}

    def _slot_capacity(self) -> int:
        """Per-slot KV rows: prompt + generation budget, with headroom
        for a ragged final chunk's pad tail at max_prompt, rounded up to
        the flash-decode KV block so the kernel never pads the cache per
        step; the tail is dead capacity the per-slot kv_len bound skips
        without reading.  Both engines and ``_check_fits`` share this."""
        need = max(self.max_prompt + self.max_new_cap,
                   _chunk_rows(self.max_prompt, self.chunk))
        return -(-need // KV_BLOCK) * KV_BLOCK

    def _init_slot_steps(self, n_slots: int) -> None:
        """Chunk-prefill / decode / reset steps over an ``n_slots`` ×
        ``self.capacity`` cache (shared by both engines)."""
        axes = slot_batch_axes(self.cfg, n_slots, self.capacity, self.prec)
        # the cache is dead after every call (immediately reassigned):
        # donate it so steps update rows in place instead of copying the
        # whole KV allocation per token
        self._chunk_step = jax.jit(
            make_chunk_prefill_step(self.cfg, axes=axes, policy=self.prec),
            donate_argnums=(1,))
        self._reset = jax.jit(
            lambda cache, empty, slot: put_slot(cache, empty, axes, slot),
            donate_argnums=(0,))
        self._release = jax.jit(release_slot, donate_argnums=(0,))
        self._empty_row = alloc_decode_cache(self.cfg, 1, self.capacity,
                                             self.prec)
        self.cache = alloc_decode_cache(self.cfg, n_slots, self.capacity,
                                        self.prec)
        # host mirror of the last emitted token per slot (decode feed)
        self._cur = np.zeros((n_slots,), np.int32)

    def _check_fits(self, prompt: np.ndarray, max_new: int) -> None:
        """Explicit capacity check at submit — any prompt that fits is
        served exactly; anything else errors instead of being silently
        truncated (the old bucket policy's failure mode)."""
        s = len(prompt)
        if s < 1:
            raise ValueError("empty prompt")
        need = max(s + max_new, _chunk_rows(s, self.chunk))
        if need > self.capacity:
            raise ValueError(
                f"prompt of {s} tokens + {max_new} new needs {need} cache"
                f" rows > slot capacity {self.capacity}; raise max_prompt/"
                f"max_new_cap (or shorten the prompt)")

    def _make_requests(self, prompts: List[np.ndarray],
                       max_new_tokens) -> List[Request]:
        if max_new_tokens is None:
            max_new_tokens = self.max_new
        if isinstance(max_new_tokens, int):
            max_new_tokens = [max_new_tokens] * len(prompts)
        assert len(max_new_tokens) == len(prompts)
        # validate the whole batch before registering anything, so a
        # rejected prompt leaves no orphaned half-submitted requests
        checked = []
        for p, mn in zip(prompts, max_new_tokens):
            p = np.asarray(p, np.int32)
            mn = max(1, min(int(mn), self.max_new_cap))
            self._check_fits(p, mn)
            checked.append((p, mn))
        now = time.perf_counter()
        reqs = []
        for p, mn in checked:
            r = Request(rid=self._next_rid, prompt=p, max_new_tokens=mn,
                        submitted_at=now)
            self._next_rid += 1
            self.requests[r.rid] = r
            reqs.append(r)
        return reqs

    def _chunk_call(self, slot, toks, poss, kvl):
        """Run one chunk step for ``slot`` (the paged engine overrides
        this to append the slot's block-table row operand)."""
        return self._chunk_step(self.params, self.cache, toks, poss,
                                slot.index, kvl)

    def _register_prefill(self, slot, prompt) -> None:
        """Hook at prefill completion (paged: publish prefix blocks)."""

    def _release_finished(self, slot) -> None:
        """Free a slot whose request finished (paged: refcount blocks)."""
        self.cache = self._release(self.cache, slot.index)
        slot.release()

    def _run_chunk(self, slot, step_clock: int) -> None:
        """One prefill chunk for ``slot``; flips it ACTIVE (and emits the
        next token) when the prompt is exhausted.  For a fresh request
        that token is its first; for a preempted request re-prefilling
        ``prompt ++ generated`` (paged engine) it is a continuation —
        the bookkeeping below is resume-aware so one implementation
        serves every engine."""
        c = self.chunk
        prompt = slot.prompt
        p = slot.chunk_pos
        r = min(c, len(prompt) - p)
        toks = np.zeros((1, c), np.int32)
        poss = np.full((1, c), -1, np.int32)
        toks[0, :r] = prompt[p:p + r]
        poss[0, :r] = np.arange(p, p + r, dtype=np.int32)
        kvl = jnp.asarray([p + c], jnp.int32)
        ntok, _, self.cache = self._chunk_call(
            slot, jnp.asarray(toks), jnp.asarray(poss), kvl)
        slot.chunk_pos += r
        if slot.chunk_pos < len(prompt):
            return
        # final chunk: its last real row's logits are the next token
        req = self.requests[slot.rid]
        self._register_prefill(slot, prompt)
        tok0 = int(np.asarray(ntok)[0, r - 1])
        req.tokens.append(tok0)
        if req.first_token_at is None:
            req.first_token_at = time.perf_counter()
        slot.begin_decode()
        slot.generated = len(req.tokens)
        if slot.generated >= slot.max_new or tok0 == self.eos_id:
            self._finish(req, step_clock)
            self._release_finished(slot)
        else:
            self._cur[slot.index] = tok0

    def _finish(self, req: Request, step_clock: int) -> None:
        req.done = True
        req.finished_at = time.perf_counter()
        req.finished_step = step_clock
        self._served.append(req)


class ContinuousBatchServer(_ServerBase):
    """Continuous batching: slot recycling between decode steps, with
    prefill chunks scheduled *inside* the decode loop.

    ``slots`` decode rows share one jitted decode step; prompts are
    consumed ``prefill_chunk`` tokens at a time (one compiled chunk
    shape, pad-free cache rows) under ``prefill_token_budget`` prefill
    tokens per decode step, so a long prompt cannot head-of-line-block
    the active slots' next tokens.  ``batch_size`` / ``prompt_len`` are
    accepted as aliases so existing callers keep working.
    """

    def __init__(self, cfg: ArchConfig, params, *,
                 slots: Optional[int] = None,
                 max_prompt: Optional[int] = None,
                 prefill_chunk: int = 8,
                 prefill_token_budget: Optional[int] = None,
                 max_new_tokens: int = 16,
                 max_new_cap: Optional[int] = None,
                 eos_id: Optional[int] = None,
                 use_artifact: bool = False,
                 batch_size: Optional[int] = None,
                 prompt_len: Optional[int] = None,
                 precision: str = "float"):
        super().__init__(cfg, params, precision)
        self.n_slots = int(slots or batch_size or 4)
        self.max_prompt = int(max_prompt or prompt_len or 32)
        self.chunk = int(prefill_chunk)
        # fairness knob: prefill tokens spent per decode step once any
        # slot is actively decoding (floored at one chunk so admission
        # always progresses); see docs/scheduling.md for the trade-off.
        self.prefill_budget = int(prefill_token_budget or self.chunk)
        self.max_new = int(max_new_tokens)
        self.max_new_cap = int(max_new_cap or max(self.max_new, 1))
        self.capacity = self._slot_capacity()
        # effective flash-decode block at this capacity — the HBM-read
        # metric quantizes to it (same helper the kernels use)
        self._kv_block = kv_block_size(self.capacity)
        self.eos_id = eos_id
        self.sched = SlotScheduler(self.n_slots)
        self._init_slot_steps(self.n_slots)
        self.artifact = None
        if use_artifact:
            from repro.core.eon_compiler import compile_serve_decode
            self.artifact = compile_serve_decode(
                cfg, self.params, slots=self.n_slots, capacity=self.capacity,
                policy=self.prec)
            self.decode = self.artifact.rehydrate()
        else:
            self.decode = jax.jit(
                make_slot_decode_step(cfg, policy=self.prec),
                donate_argnums=(1,))

    # ------------------------------------------------------------------
    def submit(self, prompts: List[np.ndarray],
               max_new_tokens: Union[int, Sequence[int], None] = None
               ) -> List[Request]:
        reqs = self._make_requests(prompts, max_new_tokens)
        for r in reqs:
            self.sched.enqueue(r)
        return reqs

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, float]:
        """Serve until queue and slots drain; returns latency metrics."""
        t0 = time.perf_counter()
        self._served: List[Request] = []
        decode_steps = 0
        prefill_chunks = 0
        occupancy: List[int] = []
        kv_fill: List[int] = []   # Σ block-rounded kv_len per decode step
        kv_raw: List[int] = []    # Σ kv_len per decode step (exact fill)

        while self.sched.busy:
            # Admission: freed slots pick up waiting requests *now*, not
            # at the end of a batch — the continuous-batching invariant.
            # One slot-row reset on device; the prefill compute itself
            # is chunked below.
            for slot, req in self.sched.admissions():
                self.cache = self._reset(self.cache, self._empty_row,
                                         slot.index)
                slot.occupy(req.rid, req.prompt, req.max_new_tokens)
                req.admitted_step = decode_steps

            # Budgeted chunk prefill, oldest request first: at most
            # prefill_budget prompt tokens per decode step (always at
            # least one chunk), so active slots keep emitting while long
            # prompts stream in.
            spent = 0
            for slot in sorted(self.sched.prefilling_slots(),
                               key=lambda s: s.rid):
                while slot.prefilling and spent < self.prefill_budget:
                    self._run_chunk(slot, decode_steps)
                    prefill_chunks += 1
                    spent += self.chunk
                if spent >= self.prefill_budget:
                    break

            active = self.sched.active_slots()
            if not active:
                continue

            tok = np.array(self._cur)
            pos = np.zeros((self.n_slots,), np.int32)
            # per-slot fill: pad-free, so fill == position + 1 exactly
            # (0 = idle or mid-prefill slot: skipped outright, and the
            # step suppresses its writes)
            kvl = np.zeros((self.n_slots,), np.int32)
            for s in active:
                pos[s.index] = s.position
                kvl[s.index] = s.position + 1
            ntok, _, self.cache = self.decode(self.params, self.cache,
                                              tok, pos, kvl)
            decode_steps += 1
            occupancy.append(len(active))
            # block-granular: the kernel fetches whole KV blocks, and
            # even an idle slot's clamped index map fetches one
            blocks = np.maximum(-(-kvl // self._kv_block), 1)
            kv_fill.append(int(blocks.sum()) * self._kv_block)
            kv_raw.append(int(kvl.sum()))
            ntok_h = np.asarray(ntok)

            for s in active:
                req = self.requests[s.rid]
                t = int(ntok_h[s.index])
                req.tokens.append(t)
                s.advance()
                self._cur[s.index] = t
                if s.generated >= s.max_new or t == self.eos_id:
                    self._finish(req, decode_steps)
                    self.cache = self._release(self.cache, s.index)
                    s.release()

        served = self._served
        wall = time.perf_counter() - t0
        self.metrics = _summarize(served, wall, engine="continuous",
                                  decode_steps=decode_steps,
                                  prefills=prefill_chunks,
                                  occupancy=occupancy,
                                  n_slots=self.n_slots)
        self.metrics["precision"] = self.precision
        self.metrics["prefill_chunk"] = self.chunk
        self.metrics["kv_cache_bytes"] = decode_cache_nbytes(self.cache)
        if kv_fill:
            # fraction of the slots × capacity rectangle the bounded
            # decode kernel reads per step (1.0 = no bounding).  Block-
            # granular at the kernel's effective block, and exact only
            # for the kv_len-bounded full-attention leaves — ring/local
            # caches carry their own position-based bound.
            # kv_fill_frac is the exact live fill (entries) — pad-free,
            # so it counts only real prompt/generated tokens — the floor
            # the read fraction approaches as capacity / block grows.
            denom = self.n_slots * self.capacity
            self.metrics["kv_read_frac"] = float(np.mean(kv_fill) / denom)
            self.metrics["kv_fill_frac"] = float(np.mean(kv_raw) / denom)
        if self.artifact is not None:
            self.metrics["artifact_bytes"] = self.artifact.artifact_bytes
        return self.metrics


class StaticBatchServer(_ServerBase):
    """Static batching baseline: the queue is drained in fixed batches
    and every batch decodes until its *slowest* member finishes — slots
    are never recycled mid-flight.  Prefill uses the same pad-free chunk
    steps as the continuous engine (run to completion up front, no
    interleaving), so token-for-token the two engines match on every
    architecture family; only scheduling differs.
    """

    def __init__(self, cfg: ArchConfig, params, *, batch_size: int = 4,
                 max_prompt: Optional[int] = None,
                 prefill_chunk: int = 8,
                 prompt_len: Optional[int] = None,
                 max_new_tokens: int = 16,
                 precision: str = "float"):
        super().__init__(cfg, params, precision)
        self.batch_size = int(batch_size)
        self.max_prompt = int(max_prompt or prompt_len or 32)
        self.chunk = int(prefill_chunk)
        self.max_new = int(max_new_tokens)
        self.max_new_cap = self.max_new
        self.eos_id = None
        self.capacity = self._slot_capacity()
        self.queue: List[Request] = []
        self._init_slot_steps(self.batch_size)
        self.decode = jax.jit(
            make_slot_decode_step(cfg, policy=self.prec),
            donate_argnums=(1,))

    def submit(self, prompts: List[np.ndarray],
               max_new_tokens: Union[int, Sequence[int], None] = None
               ) -> List[Request]:
        reqs = self._make_requests(prompts, max_new_tokens)
        self.queue.extend(reqs)
        return reqs

    def run(self) -> Dict[str, float]:
        from repro.serve.scheduler import Slot
        t0 = time.perf_counter()
        self._served: List[Request] = []
        decode_steps = 0
        prefill_chunks = 0
        while self.queue:
            batch = self.queue[:self.batch_size]
            self.queue = self.queue[self.batch_size:]
            slots = []
            for i, r in enumerate(batch):
                self.cache = self._reset(self.cache, self._empty_row, i)
                slot = Slot(i)
                slot.occupy(r.rid, r.prompt, r.max_new_tokens)
                r.admitted_step = decode_steps
                while slot.prefilling:      # full prefill, no interleave
                    self._run_chunk(slot, decode_steps)
                    prefill_chunks += 1
                slots.append(slot)
            horizon = max(r.max_new_tokens for r in batch) - 1
            # the batch decodes as one unit until its slowest member
            # drains; finished rows keep stepping (outputs discarded)
            for _ in range(horizon):
                if not any(s.active for s in slots):
                    break
                tok = np.array(self._cur)
                pos = np.zeros((self.batch_size,), np.int32)
                kvl = np.zeros((self.batch_size,), np.int32)
                for s in slots:
                    if s.active:
                        pos[s.index] = s.position
                        kvl[s.index] = s.position + 1
                ntok, _, self.cache = self.decode(self.params, self.cache,
                                                  tok, pos, kvl)
                decode_steps += 1
                ntok_h = np.asarray(ntok)
                for s in slots:
                    if not s.active:
                        continue
                    r = self.requests[s.rid]
                    t = int(ntok_h[s.index])
                    s.advance()
                    self._cur[s.index] = t
                    if not r.done:
                        r.tokens.append(t)
                        if len(r.tokens) >= r.max_new_tokens:
                            self._finish(r, decode_steps)

        served = self._served
        wall = time.perf_counter() - t0
        self.metrics = _summarize(served, wall, engine="static",
                                  decode_steps=decode_steps,
                                  prefills=prefill_chunks)
        self.metrics["precision"] = self.precision
        self.metrics["prefill_chunk"] = self.chunk
        self.metrics["kv_cache_bytes"] = decode_cache_nbytes(self.cache)
        return self.metrics


class PagedBatchServer(_ServerBase):
    """Continuous batching over a **paged KV pool** (docs/paged_kv.md).

    The contiguous engine holds a ``slots × capacity`` rectangle per
    slot: after kv_len bounding the dead tail is never *read*, but it is
    still *held* in HBM, so concurrency is priced at the worst case.
    Here the full-attention KV lives in a global pool of fixed-size
    physical blocks (block == the flash-decode KV block), each slot maps
    logical KV positions to physical blocks through a **block table**
    that rides the decode signature into the kernels' index maps, and a
    host-side ``BlockManager`` owns the pool:

    * admission gates on the free-block watermark (prompt blocks must be
      coverable), not merely on a free slot;
    * identical prompt prefixes **share physical blocks** at block
      granularity via hash-chain prefix caching (refcounted, never
      written — chunked prefill starts at the shared boundary);
    * when the pool runs dry mid-decode the youngest slot is
      **preempted**: blocks freed, request re-queued at the FCFS front,
      re-prefilled over ``prompt ++ generated`` through the ordinary
      chunked-prefill path (preempt-and-recompute; greedy decoding makes
      the recompute token-exact).

    Ring (sliding-window) caches and SSM state stay slot-addressed —
    they are already minimal (O(window)/O(state) per slot, no capacity
    tail), so paging them buys nothing; a pure-SSM family degenerates to
    plain continuous batching with pool bookkeeping disabled.  Prefix
    sharing is enabled only where *all* persistent state lives in the
    pool (uniform full-attention families); preemption works everywhere
    because recompute rebuilds slot-local state from scratch.
    """

    def __init__(self, cfg: ArchConfig, params, *,
                 slots: Optional[int] = None,
                 max_prompt: Optional[int] = None,
                 prefill_chunk: int = 8,
                 prefill_token_budget: Optional[int] = None,
                 max_new_tokens: int = 16,
                 max_new_cap: Optional[int] = None,
                 eos_id: Optional[int] = None,
                 use_artifact: bool = False,
                 pool_blocks: Optional[int] = None,
                 block_size: Optional[int] = None,
                 prefix_cache: bool = True,
                 batch_size: Optional[int] = None,
                 prompt_len: Optional[int] = None,
                 precision: str = "float"):
        super().__init__(cfg, params, precision)
        self.n_slots = int(slots or batch_size or 4)
        self.max_prompt = int(max_prompt or prompt_len or 32)
        self.chunk = int(prefill_chunk)
        self.prefill_budget = int(prefill_token_budget or self.chunk)
        self.max_new = int(max_new_tokens)
        self.max_new_cap = int(max_new_cap or max(self.max_new, 1))
        self.capacity = self._slot_capacity()
        # pool block: the kernel tile by default (maximum DMA width);
        # any smaller divisor of capacity (≥ 8, still tileable) trades
        # DMA width for allocation granularity / prefix-hit resolution
        self.block_size = self._kv_block = int(
            block_size or kv_block_size(self.capacity))
        if self.capacity % self.block_size or self.block_size < 8:
            raise ValueError(
                f"block_size {self.block_size} must divide capacity "
                f"{self.capacity} and be >= 8")
        if self.capacity % self.chunk:
            raise ValueError(
                f"prefill_chunk {self.chunk} must divide the rounded "
                f"capacity {self.capacity} (paged blocks may not "
                f"overflow the table)")
        self.n_table = self.capacity // self.block_size
        # default pool == the contiguous rectangle's block count (no
        # preemption possible); size it below slots × capacity to trade
        # HBM for occasional preempt-and-recompute.  A pool smaller than
        # one worst-case request is permitted (real requests may be
        # smaller); an individually unservable request raises at
        # admission time instead of deadlocking.
        self.pool_blocks = int(pool_blocks or self.n_slots * self.n_table)
        if self.pool_blocks < 1:
            raise ValueError("pool_blocks must be >= 1")
        self.eos_id = eos_id
        self.paged_keys = paged_cache_keys(cfg)
        # prefix reuse requires every layer's persistent decode state to
        # be (a) a function of the shared tokens alone and (b) resident
        # in the paged pool: uniform full-attention families only —
        # ring windows and SSM recurrences are slot-local and must be
        # rebuilt by an actual prefill.
        from repro.models.params import layer_pattern
        kind = layer_pattern(cfg)["kind"]
        share = bool(prefix_cache and self.paged_keys
                     and kind in ("uniform_dense", "uniform_moe"))
        self.manager = BlockManager(self.pool_blocks, self.block_size,
                                    prefix_cache=share)
        self._block_bytes = kv_pool_block_bytes(cfg, self.capacity,
                                                self.prec,
                                                self.block_size)
        self.sched = SlotScheduler(self.n_slots)
        self._init_paged_steps()
        self.preemptions = 0
        self._prompt_blocks_seen = 0
        # (rid, pool fingerprint) of the last admission that failed the
        # free-block watermark — suppresses per-step re-matching
        self._blocked_state = None
        self.artifact = None
        if use_artifact:
            from repro.core.eon_compiler import compile_serve_decode
            self.artifact = compile_serve_decode(
                cfg, self.params, slots=self.n_slots,
                capacity=self.capacity, policy=self.prec,
                pool_blocks=self.pool_blocks,
                block_size=self.block_size)
            self.decode = self.artifact.rehydrate()
        else:
            self.decode = jax.jit(
                make_paged_decode_step(cfg, policy=self.prec),
                donate_argnums=(1,))

    # ------------------------------------------------------------------
    def _init_paged_steps(self) -> None:
        axes = paged_slot_axes(self.cfg, self.n_slots, self.capacity,
                               self.pool_blocks, self.prec,
                               self.block_size)
        self._chunk_step = jax.jit(
            make_paged_chunk_prefill_step(self.cfg, axes=axes,
                                          policy=self.prec),
            donate_argnums=(1,))
        self.cache = alloc_paged_cache(self.cfg, self.n_slots,
                                       self.capacity, self.pool_blocks,
                                       self.prec, self.block_size)
        # slot-addressed leaves (ring caches, SSM state, local_pos) are
        # reset per admission exactly as in the contiguous engine; pool
        # leaves need no scrub — a new tenant's writes precede its kv_len
        shared = set(self.paged_keys) | {"pool_pos"}
        slot_keys = tuple(k for k in self.cache if k not in shared)
        self._slot_keys = slot_keys
        if slot_keys:
            slot_axes = {k: axes[k] for k in slot_keys}
            full_empty = alloc_decode_cache(self.cfg, 1, self.capacity,
                                            self.prec)
            self._empty_row = {k: full_empty[k] for k in slot_keys}

            def reset(cache, empty, slot):
                out = dict(cache)
                out.update(put_slot({k: cache[k] for k in slot_keys},
                                    empty, slot_axes, slot))
                return out

            self._reset = jax.jit(reset, donate_argnums=(0,))
        else:
            self._reset = None
        self._cur = np.zeros((self.n_slots,), np.int32)
        # host mirror of the device block-table operand (0 = unmapped:
        # always a valid physical block; dead entries are fenced by
        # kv_len, not by the table)
        self.block_table = np.zeros((self.n_slots, self.n_table), np.int32)

    # ------------------------------------------------------------------
    def submit(self, prompts: List[np.ndarray],
               max_new_tokens: Union[int, Sequence[int], None] = None
               ) -> List[Request]:
        reqs = self._make_requests(prompts, max_new_tokens)
        for r in reqs:
            self.sched.enqueue(r)
        return reqs

    # ------------------------------------------------------------------
    def _set_table_row(self, slot) -> None:
        self.block_table[slot.index, :] = 0
        if slot.blocks:
            self.block_table[slot.index, :len(slot.blocks)] = slot.blocks

    def _free_slot(self, slot) -> None:
        """FREE path: return block references (prefix-cached blocks
        survive via the registry's own reference); no device-side scrub
        — kv_len == 0 fences the slot until re-admission."""
        self.manager.free(slot.blocks)
        slot.release()
        self._set_table_row(slot)

    def _preempt(self, slot) -> None:
        """PREEMPTED: evict ``slot`` and re-queue its request at the
        FCFS front; re-admission re-prefills ``prompt ++ generated``
        (the request keeps every token already emitted)."""
        req = self.requests[slot.rid]
        self.manager.free(slot.blocks)
        slot.release()
        self._set_table_row(slot)
        req.preemptions += 1
        self.preemptions += 1
        self.sched.requeue_front(req)

    def _admit(self, decode_steps: int) -> None:
        """Admission by free-block watermark, FCFS: the queue head is
        admitted when a slot is free AND the pool covers its prefill
        rows beyond any prefix-cache hit; otherwise it (and everything
        behind it) waits."""
        while self.sched.waiting:
            free = self.sched.free_slots()
            if not free:
                return
            req = self.sched.waiting[0]
            seq = (np.concatenate([req.prompt,
                                   np.asarray(req.tokens, np.int32)])
                   if req.tokens else req.prompt)
            if not self.paged_keys:
                # pure-SSM family: no pooled leaves, no block accounting
                shared, start, need = [], 0, 0
            else:
                # a blocked head request is retried every scheduler
                # iteration: skip the (hashing + LRU-touching) prefix
                # match outright unless the pool or registry changed
                # since it last failed the watermark
                state = (req.rid, self.manager.free_blocks,
                         self.manager.live_blocks,
                         self.manager.registry_size())
                if state == self._blocked_state:
                    return
                shared = self.manager.match_prefix(seq)
                start = len(shared) * self.block_size
                # chunk-rounded prefill rows must fit the table; drop
                # shared blocks if a misaligned chunk boundary overflows
                # (dropped blocks are not used → not hits)
                while shared and (start + _chunk_rows(len(seq) - start,
                                                      self.chunk)
                                  > self.capacity):
                    self.manager.unmatch(shared[-1:])
                    shared = shared[:-1]
                    start -= self.block_size
                rows = start + _chunk_rows(len(seq) - start, self.chunk)
                need = -(-rows // self.block_size) - len(shared)
                if not self.manager.can_alloc(need):
                    # undo the match exactly — refcounts AND accounting;
                    # nothing was admitted, so nothing is counted
                    self.manager.unmatch(shared, whole_query=True)
                    if all(s.free for s in self.sched.slots):
                        # nothing running that could ever free blocks:
                        # this request is individually unservable
                        raise PoolExhausted(
                            f"request rid={req.rid} needs {need} KV "
                            f"blocks of {self.block_size} but the pool "
                            f"holds only {self.pool_blocks}")
                    self._blocked_state = state
                    return
                self._prompt_blocks_seen += max(
                    (len(seq) - 1) // self.block_size, 0)
            self._blocked_state = None
            slot = free[0]
            self.sched.waiting.popleft()
            blocks = shared + self.manager.alloc(need)
            if self._reset is not None:
                self.cache = self._reset(self.cache, self._empty_row,
                                         slot.index)
            slot.occupy(req.rid, seq, req.max_new_tokens)
            slot.blocks = blocks
            slot.chunk_pos = start          # prefill starts past the hit
            self._set_table_row(slot)
            if req.admitted_step is None:
                req.admitted_step = decode_steps

    def _chunk_call(self, slot, toks, poss, kvl):
        """Base chunk step plus the slot's block-table row operand."""
        row = jnp.asarray(self.block_table[slot.index:slot.index + 1])
        return self._chunk_step(self.params, self.cache, toks, poss,
                                slot.index, kvl, row)

    def _register_prefill(self, slot, prompt) -> None:
        """Publish the fully-written prompt blocks to the prefix cache
        (a no-op unless sharing is enabled for this family)."""
        self.manager.register_prefix(prompt, slot.blocks)

    def _release_finished(self, slot) -> None:
        self._free_slot(slot)

    def _grow_for_decode(self, active) -> list:
        """Ensure every active slot owns the block this step's write
        lands in, preempting the youngest occupied slot (LIFO, vLLM-
        style) whenever the pool runs dry.  Oldest slots grow first, so
        under pressure service order degenerates gracefully to FCFS."""
        if not self.paged_keys:
            return active                   # pure-SSM: nothing paged
        for s in sorted(active, key=lambda x: x.rid):
            while not s.free and s.position // self.block_size \
                    >= len(s.blocks):
                try:
                    s.blocks.extend(self.manager.alloc(1))
                    self.block_table[s.index,
                                     len(s.blocks) - 1] = s.blocks[-1]
                except PoolExhausted:
                    victim = self.sched.preemption_victim()
                    self._preempt(victim)
                    if victim is s:
                        break
        return [s for s in active if s.active]

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, float]:
        """Serve until queue and slots drain; returns latency metrics
        plus pool accounting (utilization, prefix hits, preemptions)."""
        t0 = time.perf_counter()
        self._served: List[Request] = []
        decode_steps = 0
        prefill_chunks = 0
        occupancy: List[int] = []
        kv_fill: List[int] = []
        kv_raw: List[int] = []
        live_hist: List[int] = []

        while self.sched.busy:
            self._admit(decode_steps)

            spent = 0
            for slot in sorted(self.sched.prefilling_slots(),
                               key=lambda s: s.rid):
                while slot.prefilling and spent < self.prefill_budget:
                    self._run_chunk(slot, decode_steps)
                    prefill_chunks += 1
                    spent += self.chunk
                if spent >= self.prefill_budget:
                    break

            active = self._grow_for_decode(self.sched.active_slots())
            if not active:
                continue

            tok = np.array(self._cur)
            pos = np.zeros((self.n_slots,), np.int32)
            kvl = np.zeros((self.n_slots,), np.int32)
            for s in active:
                pos[s.index] = s.position
                kvl[s.index] = s.position + 1
            ntok, _, self.cache = self.decode(
                self.params, self.cache, tok, pos, kvl,
                jnp.asarray(self.block_table))
            decode_steps += 1
            occupancy.append(len(active))
            live_hist.append(self.manager.live_blocks)
            blocks = np.maximum(-(-kvl // self._kv_block), 1)
            kv_fill.append(int(blocks.sum()) * self._kv_block)
            kv_raw.append(int(kvl.sum()))
            ntok_h = np.asarray(ntok)

            for s in active:
                req = self.requests[s.rid]
                t = int(ntok_h[s.index])
                req.tokens.append(t)
                s.advance()
                self._cur[s.index] = t
                if s.generated >= s.max_new or t == self.eos_id:
                    self._finish(req, decode_steps)
                    self._free_slot(s)

        served = self._served
        wall = time.perf_counter() - t0
        self.metrics = _summarize(served, wall, engine="paged",
                                  decode_steps=decode_steps,
                                  prefills=prefill_chunks,
                                  occupancy=occupancy,
                                  n_slots=self.n_slots)
        self.metrics["precision"] = self.precision
        self.metrics["prefill_chunk"] = self.chunk
        self.metrics["kv_cache_bytes"] = decode_cache_nbytes(self.cache)
        self.metrics["kv_block_bytes"] = self._block_bytes
        self.metrics["block_size"] = self.block_size
        self.metrics["pool_blocks"] = self.pool_blocks
        self.metrics["preemptions"] = self.preemptions
        st = self.manager.stats
        self.metrics["prefix_hit_blocks"] = st["prefix_hit_blocks"]
        self.metrics["prefix_hit_rate"] = (
            st["prefix_hit_blocks"] / self._prompt_blocks_seen
            if self._prompt_blocks_seen else 0.0)
        if live_hist:
            self.metrics["pool_live_blocks_mean"] = float(
                np.mean(live_hist))
            self.metrics["pool_live_blocks_peak"] = int(np.max(live_hist))
            self.metrics["pool_utilization"] = (
                float(np.mean(live_hist)) / self.pool_blocks)
            self.metrics["kv_live_bytes_peak"] = (
                int(np.max(live_hist)) * self._block_bytes)
            self.metrics["kv_live_bytes_mean"] = (
                float(np.mean(live_hist)) * self._block_bytes)
        if kv_fill:
            denom = self.n_slots * self.capacity
            self.metrics["kv_read_frac"] = float(np.mean(kv_fill) / denom)
            self.metrics["kv_fill_frac"] = float(np.mean(kv_raw) / denom)
        if self.artifact is not None:
            self.metrics["artifact_bytes"] = self.artifact.artifact_bytes
        return self.metrics


# Default engine: continuous batching (what the old name promised).
BatchServer = ContinuousBatchServer
