"""Serving engines — the "EIM process runner" analogue (paper §4.6):
a deployed artifact behind a queue-driven I/O interface.

Two schedulers over the same model serve steps:

* ``ContinuousBatchServer`` (the default ``BatchServer``) — slot-based
  continuous batching.  Finished sequences release their KV-cache slot
  *between decode steps* and waiting requests are admitted into freed
  slots; per-request ``max_new_tokens`` is honored in-step.  Prefill is
  compiled once per padded bucket; optionally the decode hot loop runs a
  ``CompiledArtifact`` (``core/eon_compiler.compile_serve_decode``) so
  serving executes the same AOT executable we "deploy" (paper C4).
* ``StaticBatchServer`` — the classic baseline: a batch is formed once
  and decodes until its slowest member finishes; short requests block
  behind long ones.  Kept as the benchmark control.

Both engines accept ``precision="float" | "int8"`` (paper C5 threaded
end-to-end): int8 wraps projection weights in QTensor once at
construction, serves through the quant-aware matmul entry point, and
keeps the decode cache as Int8KV — ≥2× KV HBM, token-exact against the
fake-quant float reference (docs/quantization.md).

Both feed the decode step a per-slot ``kv_len`` (the scheduler's fill
high-water mark; 0 for idle slots) so the flash-decode kernel reads
only each slot's live prefix of the capacity rectangle — and int8
decode dequantizes inside the kernel tile, never materializing a float
cache (docs/serving.md, "Flash-decode kernel").

Both left-pad prompts into the prefill bucket with position −1 marking
pad entries, which the attention masks treat as never-attendable, so
batched serving is token-exact versus an unpadded single-request decode
for attention architectures.  (SSM/hybrid recurrences still traverse pad
inputs — see docs/serving.md for the caveat.)
"""
from __future__ import annotations

import dataclasses
import time
from typing import Dict, List, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arch import ArchConfig
from repro.core.quantize import policy_for, quantize_model_params
from repro.serve.kvcache import (alloc_decode_cache, decode_cache_nbytes,
                                 grow_cache, release_slot, write_slot)
from repro.serve.scheduler import BucketPolicy, SlotScheduler
from repro.serve.serve_step import make_prefill_step, make_slot_decode_step

# Decode-cache capacity granularity: one flash-decode KV block (a
# sub-multiple of kernels/flash_decode.py's block_k, so any rounded
# capacity tiles cleanly on every backend).
KV_BLOCK = 64


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray              # (S,) int32
    max_new_tokens: int = 16
    submitted_at: float = 0.0
    tokens: List[int] = dataclasses.field(default_factory=list)
    done: bool = False
    first_token_at: Optional[float] = None
    finished_at: Optional[float] = None
    admitted_step: Optional[int] = None   # decode-step clock at admission
    finished_step: Optional[int] = None


def _check_supported(cfg: ArchConfig) -> None:
    if cfg.is_encdec or cfg.frontend:
        raise NotImplementedError(
            f"{cfg.name}: serving engine requires a token-input decoder-only"
            " architecture (enc-dec / embedding-frontend archs need a"
            " modality runner in front)")


def _left_pad(prompt: np.ndarray, bucket: int):
    """Pad/truncate into the bucket.  Returns (tokens, positions); pad
    entries get position −1, which every attention mask rejects."""
    p = np.asarray(prompt, np.int32)[-bucket:]
    tokens = np.zeros((bucket,), np.int32)
    positions = np.full((bucket,), -1, np.int32)
    if len(p):
        tokens[-len(p):] = p
        positions[-len(p):] = np.arange(len(p), dtype=np.int32)
    return tokens, positions, len(p)


def _summarize(served: List[Request], wall: float, *, engine: str,
               decode_steps: int, prefills: int,
               occupancy: Optional[List[int]] = None,
               n_slots: int = 0) -> Dict[str, float]:
    ttfts = np.array([r.first_token_at - r.submitted_at for r in served])
    gen = sum(len(r.tokens) for r in served)
    m: Dict[str, float] = {
        "engine": engine,
        "requests": len(served),
        "wall_s": wall,
        "ttft_mean_s": float(ttfts.mean()) if len(ttfts) else 0.0,
        "ttft_p50_s": float(np.percentile(ttfts, 50)) if len(ttfts) else 0.0,
        "ttft_p95_s": float(np.percentile(ttfts, 95)) if len(ttfts) else 0.0,
        "tokens_generated": gen,
        "tokens_per_s": gen / max(wall, 1e-9),
        "decode_steps": decode_steps,
        "prefills": prefills,
    }
    if occupancy and n_slots:
        m["mean_active_slots"] = float(np.mean(occupancy))
        m["slot_utilization"] = float(np.mean(occupancy)) / n_slots
    return m


class _ServerBase:
    def __init__(self, cfg: ArchConfig, params, precision: str = "float"):
        _check_supported(cfg)
        self.cfg = cfg
        self.precision = precision
        self.prec = policy_for(precision)
        # int8: projection weights become QTensor leaves once, up front —
        # the serving hot loop never sees a float weight again.
        self.params = quantize_model_params(params, self.prec)
        self._next_rid = 0
        self.requests: Dict[int, Request] = {}
        self.metrics: Dict[str, float] = {}

    def _make_requests(self, prompts: List[np.ndarray],
                       max_new_tokens) -> List[Request]:
        if max_new_tokens is None:
            max_new_tokens = self.max_new
        if isinstance(max_new_tokens, int):
            max_new_tokens = [max_new_tokens] * len(prompts)
        assert len(max_new_tokens) == len(prompts)
        now = time.perf_counter()
        reqs = []
        for p, mn in zip(prompts, max_new_tokens):
            r = Request(rid=self._next_rid, prompt=np.asarray(p, np.int32),
                        max_new_tokens=max(1, min(int(mn), self.max_new_cap)),
                        submitted_at=now)
            self._next_rid += 1
            self.requests[r.rid] = r
            reqs.append(r)
        return reqs


class ContinuousBatchServer(_ServerBase):
    """Continuous batching: slot recycling between decode steps.

    ``slots`` decode rows share one jitted decode step; prompts prefill
    one at a time into the smallest padded bucket (one compilation per
    bucket) and are spliced into a free slot row.  ``batch_size`` /
    ``prompt_len`` are accepted as aliases so existing callers keep
    working.
    """

    def __init__(self, cfg: ArchConfig, params, *,
                 slots: Optional[int] = None,
                 buckets: Optional[Sequence[int]] = None,
                 max_new_tokens: int = 16,
                 max_new_cap: Optional[int] = None,
                 eos_id: Optional[int] = None,
                 use_artifact: bool = False,
                 batch_size: Optional[int] = None,
                 prompt_len: Optional[int] = None,
                 precision: str = "float"):
        super().__init__(cfg, params, precision)
        self.n_slots = int(slots or batch_size or 4)
        self.policy = BucketPolicy(buckets or (prompt_len or 32,))
        self.max_new = int(max_new_tokens)
        self.max_new_cap = int(max_new_cap or max(self.max_new, 1))
        # Capacity rounds up to the flash-decode KV block so the kernel
        # never pads the cache per step; the tail is dead capacity the
        # per-slot kv_len bound skips without reading.
        need = self.policy.max_bucket + self.max_new_cap
        self.capacity = -(-need // KV_BLOCK) * KV_BLOCK
        # effective flash-decode block at this capacity (mirrors the
        # kernel's choice: min(128, S), halved until it divides S) —
        # the HBM-read metric quantizes to it
        bk = min(128, self.capacity)
        while self.capacity % bk and bk > 8:
            bk //= 2
        self._kv_block = bk
        self.eos_id = eos_id
        self.sched = SlotScheduler(self.n_slots)
        self.prefill = jax.jit(make_prefill_step(cfg, policy=self.prec))
        # the cache is dead after every call (immediately reassigned):
        # donate it so steps update rows in place instead of copying the
        # whole KV allocation per token
        self._write = jax.jit(write_slot, donate_argnums=(0,))
        self._release = jax.jit(release_slot, donate_argnums=(0,))
        self.artifact = None
        if use_artifact:
            from repro.core.eon_compiler import compile_serve_decode
            self.artifact = compile_serve_decode(
                cfg, self.params, slots=self.n_slots, capacity=self.capacity,
                policy=self.prec)
            self.decode = self.artifact.rehydrate()
        else:
            self.decode = jax.jit(
                make_slot_decode_step(cfg, policy=self.prec),
                donate_argnums=(1,))
        self.cache = alloc_decode_cache(cfg, self.n_slots, self.capacity,
                                        self.prec)
        # host mirror of the last emitted token per slot (decode feed)
        self._cur = np.zeros((self.n_slots,), np.int32)

    # ------------------------------------------------------------------
    def submit(self, prompts: List[np.ndarray],
               max_new_tokens: Union[int, Sequence[int], None] = None
               ) -> List[Request]:
        reqs = self._make_requests(prompts, max_new_tokens)
        for r in reqs:
            self.sched.enqueue(r)
        return reqs

    # ------------------------------------------------------------------
    def _admit(self, slot, req: Request, step_clock: int) -> bool:
        """Prefill into the smallest bucket and splice into the slot.
        Returns True when the request keeps the slot (needs decoding)."""
        bucket = self.policy.bucket_for(len(req.prompt))
        tokens, positions, plen = _left_pad(req.prompt, bucket)
        inputs = {"tokens": jnp.asarray(tokens[None, :]),
                  "positions": jnp.asarray(positions[None, :])}
        next_tok, _, small = self.prefill(self.params, inputs)
        tok0 = int(np.asarray(next_tok)[0])
        req.tokens.append(tok0)
        req.first_token_at = time.perf_counter()
        req.admitted_step = step_clock
        if req.max_new_tokens <= 1 or tok0 == self.eos_id:
            self._finish(req, step_clock)
            return False
        self.cache = self._write(self.cache, small, slot.index)
        slot.occupy(req.rid, plen, bucket, req.max_new_tokens)
        self._cur[slot.index] = tok0
        return True

    def _finish(self, req: Request, step_clock: int) -> None:
        req.done = True
        req.finished_at = time.perf_counter()
        req.finished_step = step_clock

    # ------------------------------------------------------------------
    def run(self) -> Dict[str, float]:
        """Serve until queue and slots drain; returns latency metrics."""
        t0 = time.perf_counter()
        served: List[Request] = []
        decode_steps = 0
        prefills = 0
        occupancy: List[int] = []
        kv_fill: List[int] = []   # Σ block-rounded kv_len per decode step
        kv_raw: List[int] = []    # Σ kv_len per decode step (slot fill)

        while self.sched.busy:
            # Admission: freed slots pick up waiting requests *now*, not
            # at the end of a batch — the continuous-batching invariant.
            for slot, req in self.sched.admissions():
                prefills += 1
                if not self._admit(slot, req, decode_steps):
                    served.append(req)
            active = self.sched.active_slots()
            if not active:
                continue

            tok = np.array(self._cur)
            pos = np.zeros((self.n_slots,), np.int32)
            widx = np.full((self.n_slots,), self.capacity - 1, np.int32)
            # per-slot KV high-water mark: the decode kernel reads only
            # kv_len rows per slot (0 = idle slot, skipped outright)
            kvl = np.zeros((self.n_slots,), np.int32)
            for s in active:
                pos[s.index] = s.position
                widx[s.index] = s.write_idx
                kvl[s.index] = s.write_idx + 1
            ntok, _, self.cache = self.decode(self.params, self.cache,
                                              tok, pos, widx, kvl)
            decode_steps += 1
            occupancy.append(len(active))
            # block-granular: the kernel fetches whole KV blocks, and
            # even an idle slot's clamped index map fetches one
            blocks = np.maximum(-(-kvl // self._kv_block), 1)
            kv_fill.append(int(blocks.sum()) * self._kv_block)
            kv_raw.append(int(kvl.sum()))
            ntok_h = np.asarray(ntok)

            for s in active:
                req = self.requests[s.rid]
                t = int(ntok_h[s.index])
                req.tokens.append(t)
                s.advance()
                self._cur[s.index] = t
                if s.generated >= s.max_new or t == self.eos_id:
                    self._finish(req, decode_steps)
                    served.append(req)
                    self.cache = self._release(self.cache, s.index)
                    s.release()

        wall = time.perf_counter() - t0
        self.metrics = _summarize(served, wall, engine="continuous",
                                  decode_steps=decode_steps,
                                  prefills=prefills, occupancy=occupancy,
                                  n_slots=self.n_slots)
        self.metrics["precision"] = self.precision
        self.metrics["kv_cache_bytes"] = decode_cache_nbytes(self.cache)
        if kv_fill:
            # fraction of the slots × capacity rectangle the bounded
            # decode kernel reads per step (1.0 = no bounding).  Block-
            # granular at the kernel's effective block, and exact only
            # for the kv_len-bounded full-attention leaves — ring/local
            # caches carry their own position-based bound.
            # kv_fill_frac is the raw slot fill (entries), the floor the
            # read fraction approaches as capacity / block grows.
            denom = self.n_slots * self.capacity
            self.metrics["kv_read_frac"] = float(np.mean(kv_fill) / denom)
            self.metrics["kv_fill_frac"] = float(np.mean(kv_raw) / denom)
        if self.artifact is not None:
            self.metrics["artifact_bytes"] = self.artifact.artifact_bytes
        return self.metrics


class StaticBatchServer(_ServerBase):
    """Static batching baseline: the queue is drained in fixed batches
    and every batch decodes until its *slowest* member finishes — slots
    are never recycled mid-flight.  Token-for-token it matches the
    continuous engine (same left-pad masking); only scheduling differs.
    """

    def __init__(self, cfg: ArchConfig, params, *, batch_size: int = 4,
                 prompt_len: int = 32, max_new_tokens: int = 16,
                 precision: str = "float"):
        super().__init__(cfg, params, precision)
        self.batch_size = int(batch_size)
        self.prompt_len = int(prompt_len)
        self.max_new = int(max_new_tokens)
        self.max_new_cap = self.max_new
        self.queue: List[Request] = []
        self._cache_bytes = 0
        self.prefill = jax.jit(make_prefill_step(cfg, policy=self.prec))
        self.decode = jax.jit(
            make_slot_decode_step(cfg, policy=self.prec),
            donate_argnums=(1,))

    def submit(self, prompts: List[np.ndarray],
               max_new_tokens: Union[int, Sequence[int], None] = None
               ) -> List[Request]:
        reqs = self._make_requests(prompts, max_new_tokens)
        self.queue.extend(reqs)
        return reqs

    def run(self) -> Dict[str, float]:
        t0 = time.perf_counter()
        served: List[Request] = []
        decode_steps = 0
        prefills = 0
        self._cache_bytes = 0
        while self.queue:
            batch = self.queue[:self.batch_size]
            self.queue = self.queue[self.batch_size:]
            b = len(batch)
            tokens = np.zeros((b, self.prompt_len), np.int32)
            positions = np.full((b, self.prompt_len), -1, np.int32)
            plens = np.zeros((b,), np.int32)
            for i, r in enumerate(batch):
                tokens[i], positions[i], plens[i] = _left_pad(
                    r.prompt, self.prompt_len)
            next_tok, _, cache = self.prefill(
                self.params, {"tokens": jnp.asarray(tokens),
                              "positions": jnp.asarray(positions)})
            prefills += 1
            horizon = max(r.max_new_tokens for r in batch) - 1
            cache = grow_cache(self.cfg, cache, horizon + 1)
            self._cache_bytes = max(self._cache_bytes,
                                    decode_cache_nbytes(cache))
            now = time.perf_counter()
            ntok = np.asarray(next_tok)
            for i, r in enumerate(batch):
                r.tokens.append(int(ntok[i]))
                r.first_token_at = now
                r.admitted_step = decode_steps
                if r.max_new_tokens <= 1:
                    r.done = True
                    r.finished_at = now
                    r.finished_step = decode_steps
            cur = next_tok
            for step in range(horizon):
                pos = jnp.asarray(plens + step)
                widx = jnp.full((b,), self.prompt_len + step, jnp.int32)
                kvl = jnp.full((b,), self.prompt_len + step + 1, jnp.int32)
                cur, _, cache = self.decode(self.params, cache, cur, pos,
                                            widx, kvl)
                decode_steps += 1
                ctok = np.asarray(cur)
                for i, r in enumerate(batch):
                    if not r.done:
                        r.tokens.append(int(ctok[i]))
                        if len(r.tokens) >= r.max_new_tokens:
                            r.done = True
                            r.finished_at = time.perf_counter()
                            r.finished_step = decode_steps
            served.extend(batch)

        wall = time.perf_counter() - t0
        self.metrics = _summarize(served, wall, engine="static",
                                  decode_steps=decode_steps,
                                  prefills=prefills)
        self.metrics["precision"] = self.precision
        if self._cache_bytes:
            self.metrics["kv_cache_bytes"] = self._cache_bytes
        return self.metrics


# Default engine: continuous batching (what the old name promised).
BatchServer = ContinuousBatchServer
