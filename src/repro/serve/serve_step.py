"""serve_step factories: prefill, chunked prefill, and one-token decode,
policy-wrapped.

Each factory takes an optional ``PrecisionPolicy`` (core/quantize):
the step closes over it, so float and int8 servers lower distinct
(but same-signature) executables.

``decode_*`` shapes lower ``decode_step`` (one new token against a KV
cache of seq_len), ``prefill_*`` shapes lower ``prefill_step`` — per the
assignment's cell semantics.  ``chunk_prefill_step`` is the admission
path of chunked pad-free prefill: one fixed-size chunk of C prompt
tokens against one slot's live cache row, compiled once per chunk shape
(instead of once per padded bucket).

With pad-free admission a cache row's index always equals its entry's
absolute position, so the slot decode step derives its write index from
``position`` and carries only the per-slot ``kv_len`` fill — the
scheduler's exact live length, no pad region (see docs/scheduling.md
for the invariants).
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.arch import ArchConfig
from repro.models.api import model_fns
from repro.serve.kvcache import put_slot, take_slot
from repro.sharding.policy import AxisRules, use_rules


def _context(fn, rules, mesh):
    if rules is None or mesh is None:
        return fn

    @functools.wraps(fn)
    def wrapped(*a, **k):
        with use_rules(rules, mesh):
            return fn(*a, **k)
    return wrapped


def make_prefill_step(cfg: ArchConfig, *, rules: Optional[AxisRules] = None,
                      mesh=None, policy=None):
    fns = model_fns(cfg)

    def prefill_step(params, inputs):
        logits, cache = fns.forward_prefill(cfg, params, inputs, policy)
        # greedy next token (sampling lives host-side in the server loop)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, cache

    return _context(prefill_step, rules, mesh)


def make_chunk_prefill_step(cfg: ArchConfig, *, axes=None,
                            rules: Optional[AxisRules] = None, mesh=None,
                            policy=None):
    """Chunked pad-free prefill step (the serving admission path).

    Without ``axes``: ``step(params, cache, tokens, positions, kv_len)``
    runs one (B, C) chunk against a batch-matched cache — the model-
    level building block.

    With ``axes`` (a ``kvcache.slot_batch_axes`` pytree): the step takes
    the *big* slots × capacity cache plus a traced ``slot`` index,
    slices that slot's row out, runs the chunk at batch 1, and splices
    the row back — so a prefill chunk costs one slot's attention, not
    the whole batch's: ``step(params, cache, tokens, positions, slot,
    kv_len) -> (next_tokens (1, C), logits, new_cache)``.

    ``tokens``/``positions``: (B, C) with the pad tail of a ragged final
    chunk at position −1; ``kv_len``: (B,) post-write fill ``p + C``.
    The chunk's write offset is ``positions[:, 0]`` (the first entry of
    a chunk is always a real token).
    """
    fns = model_fns(cfg)

    def chunk_step(params, cache, tokens, positions, kv_len):
        logits, new_cache = fns.forward_prefill_chunk(
            cfg, params, cache, tokens, positions, policy=policy,
            kv_len=kv_len)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, logits, new_cache

    if axes is None:
        return _context(chunk_step, rules, mesh)

    def slot_chunk_step(params, cache, tokens, positions, slot, kv_len):
        small = take_slot(cache, axes, slot)
        next_tokens, logits, new_small = chunk_step(params, small, tokens,
                                                    positions, kv_len)
        return next_tokens, logits, put_slot(cache, new_small, axes, slot)

    return _context(slot_chunk_step, rules, mesh)


def make_paged_chunk_prefill_step(cfg: ArchConfig, *, axes,
                                  rules: Optional[AxisRules] = None,
                                  mesh=None, policy=None):
    """Chunk-prefill step over the **paged** decode cache.

    ``axes`` comes from ``kvcache.paged_slot_axes``: slot-addressed
    leaves (ring caches, SSM state) are sliced/spliced per slot exactly
    as in ``make_chunk_prefill_step``, while the paged pool leaves pass
    through whole — the chunk addresses them via ``block_row``, the
    (1, n_blocks) block-table row of the slot being prefilled:
    ``step(params, cache, tokens, positions, slot, kv_len, block_row)``.
    ``kv_len`` stays the *logical* post-write fill ``p + C``.
    """
    fns = model_fns(cfg)

    def paged_chunk_step(params, cache, tokens, positions, slot, kv_len,
                         block_row):
        small = take_slot(cache, axes, slot)
        logits, new_small = fns.forward_prefill_chunk(
            cfg, params, small, tokens, positions, policy=policy,
            kv_len=kv_len, block_table=block_row)
        next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tokens, logits, put_slot(cache, new_small, axes, slot)

    return _context(paged_chunk_step, rules, mesh)


def make_decode_step(cfg: ArchConfig, *, rules: Optional[AxisRules] = None,
                     mesh=None, policy=None):
    fns = model_fns(cfg)

    def decode_step(params, cache, token, position):
        logits, new_cache = fns.forward_decode(cfg, params, cache, token,
                                               position, policy=policy)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, new_cache

    return _context(decode_step, rules, mesh)


def make_slot_decode_step(cfg: ArchConfig, *,
                          rules: Optional[AxisRules] = None, mesh=None,
                          policy=None):
    """Decode step over the slot-addressed cache (continuous batching).

    ``policy`` (``PrecisionPolicy``) selects the weight/activation/KV
    precision the step lowers with — it is part of the compiled
    artifact's identity, not a runtime argument.

    ``kv_len`` (B,) is the scheduler's exact per-slot fill: with pad-free
    chunked admission a cache row's index equals its entry's absolute
    position, so the write index is simply ``position`` and the
    post-write fill is ``position + 1``.  ``kv_len == 0`` marks a slot
    that is idle or mid-prefill: the decode attention skips its row
    outright AND every cache/state write for it is suppressed, so decode
    steps can interleave with chunked prefill on the same cache.  The
    caller owns the contract that entries at index >= kv_len are invalid
    — which pad-free admission guarantees (chunks write ``[p, p + C)``
    exactly; decode writes advance the fill by one).
    """
    fns = model_fns(cfg)

    def decode_step(params, cache, token, position, kv_len):
        logits, new_cache = fns.forward_decode(cfg, params, cache, token,
                                               position, policy=policy,
                                               kv_len=kv_len)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, new_cache

    return _context(decode_step, rules, mesh)


def make_paged_decode_step(cfg: ArchConfig, *,
                           rules: Optional[AxisRules] = None, mesh=None,
                           policy=None):
    """Decode step over the paged decode cache (paged continuous
    batching): ``step(params, cache, token, position, kv_len,
    block_table)`` — the slot decode contract of
    ``make_slot_decode_step`` plus the (slots, n_blocks) block table
    that resolves each slot's logical KV blocks to physical pool blocks
    (docs/paged_kv.md).  ``kv_len == 0`` still marks idle/mid-prefill
    rows: reads skip them and their writes are routed out of bounds.
    """
    fns = model_fns(cfg)

    def decode_step(params, cache, token, position, kv_len, block_table):
        logits, new_cache = fns.forward_decode(cfg, params, cache, token,
                                               position, policy=policy,
                                               kv_len=kv_len,
                                               block_table=block_table)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, new_cache

    return _context(decode_step, rules, mesh)
