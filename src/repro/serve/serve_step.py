"""serve_step factories: prefill and one-token decode, policy-wrapped.

Each factory takes an optional ``PrecisionPolicy`` (core/quantize):
the step closes over it, so float and int8 servers lower distinct
(but same-signature) executables.

``decode_*`` shapes lower ``decode_step`` (one new token against a KV
cache of seq_len), ``prefill_*`` shapes lower ``prefill_step`` — per the
assignment's cell semantics.

The decode step takes an explicit per-sequence ``write_idx`` so the
continuous-batching engine can keep cache rows slot-addressed (index ≠
absolute position once prompts are left-padded into buckets); plain
callers pass ``write_idx == position``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.arch import ArchConfig
from repro.models.api import model_fns
from repro.sharding.policy import AxisRules, use_rules


def _context(fn, rules, mesh):
    if rules is None or mesh is None:
        return fn

    @functools.wraps(fn)
    def wrapped(*a, **k):
        with use_rules(rules, mesh):
            return fn(*a, **k)
    return wrapped


def make_prefill_step(cfg: ArchConfig, *, rules: Optional[AxisRules] = None,
                      mesh=None, policy=None):
    fns = model_fns(cfg)

    def prefill_step(params, inputs):
        logits, cache = fns.forward_prefill(cfg, params, inputs, policy)
        # greedy next token (sampling lives host-side in the server loop)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, cache

    return _context(prefill_step, rules, mesh)


def make_decode_step(cfg: ArchConfig, *, rules: Optional[AxisRules] = None,
                     mesh=None, policy=None):
    fns = model_fns(cfg)

    def decode_step(params, cache, token, position):
        logits, new_cache = fns.forward_decode(cfg, params, cache, token,
                                               position, policy=policy)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, new_cache

    return _context(decode_step, rules, mesh)


def make_slot_decode_step(cfg: ArchConfig, *,
                          rules: Optional[AxisRules] = None, mesh=None,
                          policy=None):
    """Decode step with slot-addressed cache writes (continuous batching).

    ``policy`` (``PrecisionPolicy``) selects the weight/activation/KV
    precision the step lowers with — it is part of the compiled
    artifact's identity, not a runtime argument.

    ``kv_len`` (B,) is the scheduler's per-slot fill (high-water mark +
    1 for the entry this step writes; 0 for idle slots): the decode
    attention kernel reads only ``kv_len`` cache rows per slot instead
    of the full capacity rectangle.  The caller owns the contract that
    entries at index >= kv_len are invalid (position −1) — which the
    slot API guarantees (write_slot wipes the row, decode writes advance
    the mark by one).
    """
    fns = model_fns(cfg)

    def decode_step(params, cache, token, position, write_idx, kv_len):
        logits, new_cache = fns.forward_decode(cfg, params, cache, token,
                                               position, write_idx,
                                               policy=policy, kv_len=kv_len)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_token, logits, new_cache

    return _context(decode_step, rules, mesh)
