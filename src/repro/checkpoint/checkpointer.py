"""Sharded, atomic, versioned checkpointing (fault-tolerance substrate).

Layout: ``<root>/step_<N>/`` holding one ``.npy`` per addressable shard
per leaf plus a manifest describing the tree structure and each leaf's
sharding.  Writes are atomic (temp dir + manifest-last + rename), so a
killed writer never leaves a readable-but-wrong checkpoint; restore
validates the manifest and can **reshard** onto a different mesh
(elastic scaling: the manifest stores global shapes, shards are
reassembled and re-split for whatever mesh the restoring job brings).
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple

import jax
import numpy as np


def _leaf_paths(tree) -> List[Tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out


class Checkpointer:
    def __init__(self, root: Path, keep: int = 3):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.keep = keep

    # ------------------------------------------------------------------
    def save(self, step: int, tree, extra: Optional[Dict] = None) -> Path:
        final = self.root / f"step_{step:08d}"
        tmp = self.root / f".tmp_step_{step:08d}_{os.getpid()}"
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        manifest: Dict[str, Any] = {"step": step, "time": time.time(),
                                    "leaves": {}, "extra": extra or {}}
        for key, leaf in _leaf_paths(tree):
            arr = leaf
            fname = key.replace("/", "__") + ".npy"
            if isinstance(arr, jax.Array):
                shards = []
                for i, s in enumerate(arr.addressable_shards):
                    # name must end in .npy or np.save appends another one
                    sname = f"{fname[:-4]}.shard{i}.npy"
                    np.save(tmp / sname, np.asarray(s.data))
                    shards.append({"file": sname,
                                   "index": _index_to_json(s.index)})
                manifest["leaves"][key] = {
                    "shape": list(arr.shape), "dtype": str(arr.dtype),
                    "shards": shards}
            else:
                np.save(tmp / fname, np.asarray(arr))
                manifest["leaves"][key] = {
                    "shape": list(np.shape(arr)),
                    "dtype": str(np.asarray(arr).dtype),
                    "shards": [{"file": fname, "index": None}]}
        # manifest written LAST, then atomic rename
        (tmp / "manifest.json").write_text(json.dumps(manifest))
        if final.exists():
            shutil.rmtree(final)
        tmp.rename(final)
        self._gc()
        return final

    def _gc(self):
        steps = self.all_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(self.root / f"step_{s:08d}", ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> List[int]:
        out = []
        for p in self.root.glob("step_*"):
            if (p / "manifest.json").exists():   # incomplete = invisible
                out.append(int(p.name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, tree_like, step: Optional[int] = None,
                shardings=None) -> Tuple[Any, Dict]:
        """Restore into the structure of ``tree_like``; ``shardings`` (an
        optional matching pytree) reshards onto the restoring mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.root}")
        cdir = self.root / f"step_{step:08d}"
        manifest = json.loads((cdir / "manifest.json").read_text())

        leaves, treedef = jax.tree_util.tree_flatten(tree_like)
        keyed = _leaf_paths(tree_like)
        shard_list = (jax.tree_util.tree_leaves(shardings)
                      if shardings is not None else [None] * len(leaves))
        out = []
        for (key, ref), sh in zip(keyed, shard_list):
            rec = manifest["leaves"][key]
            full = np.zeros(rec["shape"], dtype=rec["dtype"]) \
                if rec["shards"][0]["index"] is not None else None
            if full is None:
                arr = np.load(cdir / rec["shards"][0]["file"])
            else:
                for srec in rec["shards"]:
                    piece = np.load(cdir / srec["file"])
                    full[_json_to_index(srec["index"])] = piece
                arr = full
            if sh is not None:
                out.append(jax.device_put(arr, sh))
            else:
                out.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, out), manifest["extra"]


def _index_to_json(index) -> List:
    out = []
    for sl in index:
        out.append([sl.start, sl.stop, sl.step])
    return out


def _json_to_index(spec) -> Tuple:
    return tuple(slice(a, b, c) for a, b, c in spec)
