"""Mixture-of-experts layer: top-k routing, capacity dispatch, EP.

Two dispatch implementations with identical capacity-dropping semantics
(Switch/GShard-style: per-token top-k, per-expert capacity, overflow
dropped):

* ``moe_layer_dense`` — pjit scatter dispatch.  Correct everywhere, but
  GSPMD lowers the token→expert scatter to a replicated buffer +
  all-reduce: fine at smoke scale, catastrophic on a pod (measured:
  +54 GiB/device, 26 s collective term on phi3.5 prefill_32k).  Kept as
  the naive baseline and for meshes the shard_map path can't divide.
* ``moe_layer_a2a``   — production EP path under ``shard_map``: each
  (data, model) device routes its token sub-slice locally, exchanges
  expert slabs with ``all_to_all`` over the model axis, computes its
  resident expert, and reverses the exchange.  FSDP-stored expert
  weights are all-gathered over "data" explicitly inside the region.

``moe_layer`` picks automatically (a2a needs tokens divisible by the
full mesh and experts divisible by the model axis).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.arch import ArchConfig
from repro.sharding.policy import (axis_assignment_size, constrain,
                                   current_mesh_rules)


def route_topk(router_logits: jax.Array, k: int
               ) -> Tuple[jax.Array, jax.Array]:
    """(T, E) logits -> (T, k) expert ids + normalized weights (f32)."""
    weights, idx = jax.lax.top_k(router_logits.astype(jnp.float32), k)
    weights = jax.nn.softmax(weights, axis=-1)
    return idx, weights


def _dispatch_indices(logits: jax.Array, k: int, e: int, capacity: int):
    """Shared routing bookkeeping: (T, E) logits -> flat_e, slot_c, keep, w."""
    t = logits.shape[0]
    expert_idx, weights = route_topk(logits, k)                # (T, k)
    flat_e = expert_idx.reshape(t * k)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)
    pos_in_expert = jnp.cumsum(onehot, axis=0) - onehot
    slot = jnp.take_along_axis(pos_in_expert, flat_e[:, None], axis=1)[:, 0]
    keep = slot < capacity
    slot_c = jnp.where(keep, slot, capacity)
    return flat_e, slot_c, keep, weights


def _expert_ffn(buf: jax.Array, wg, wu, wd) -> jax.Array:
    """(E, C, d) @ per-expert SwiGLU -> (E, C, d)."""
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
    h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
    h = constrain(h, ("act_experts", "act_expert_cap", "act_ff"))
    return jnp.einsum("ecf,efd->ecd", h, wd)


def moe_layer(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Auto-dispatch between the a2a (production) and dense (fallback)
    EP implementations."""
    mesh, rules = current_mesh_rules()
    if mesh is not None and rules is not None and "model" in mesh.shape:
        model_sz = mesh.shape["model"]
        dp_sz = axis_assignment_size(mesh, rules.get("act_batch"))
        t = x.shape[0] * x.shape[1]
        if (model_sz > 1 and cfg.n_experts % model_sz == 0
                and t % (dp_sz * model_sz) == 0
                and t // (dp_sz * model_sz) >= 8
                and x.shape[0] % dp_sz == 0):
            return moe_layer_a2a(p, x, cfg, mesh, rules)
    return moe_layer_dense(p, x, cfg)


def moe_layer_a2a(p: dict, x: jax.Array, cfg: ArchConfig, mesh, rules
                  ) -> jax.Array:
    """shard_map EP: local routing → a2a over "model" → resident expert →
    reverse a2a → local combine.  Output returns sequence-sharded over the
    model axis (Megatron-SP style); the caller's residual constraint
    all-gathers it back.
    """
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_tok
    t = b * s
    batch_assign = rules.get("act_batch") or ()
    batch_axes = ((batch_assign,) if isinstance(batch_assign, str)
                  else tuple(batch_assign))
    batch_axes = tuple(a for a in batch_axes if a in mesh.shape)
    dp_sz = 1
    for a in batch_axes:
        dp_sz *= mesh.shape[a]
    model_sz = mesh.shape["model"]
    t_sub = t // (dp_sz * model_sz)          # tokens per (dp, model) device
    e_loc = e // model_sz
    capacity = max(_round_up(int(cfg.capacity_factor * t_sub * k / e), 8), 8)

    rows_spec = P(batch_axes + ("model",), None) if batch_axes \
        else P("model", None)
    out_spec = rows_spec

    def body(rows, router, wg, wu, wd):
        # rows: (t_sub, d) local token sub-slice (model axis splits rows).
        logits = rows @ router                                  # (t_sub, E)
        flat_e, slot_c, keep, weights = _dispatch_indices(
            logits, k, e, capacity)
        xk = jnp.repeat(rows, k, axis=0)
        buf = jnp.zeros((e, capacity + 1, d), rows.dtype) \
            .at[flat_e, slot_c].add(xk)
        buf = buf[:, :capacity, :]
        # exchange: every peer sends expert-m slab to model-rank m
        buf = lax.all_to_all(buf, "model", split_axis=0, concat_axis=1,
                             tiled=True)                        # (e_loc, C*msz, d)
        wg = lax.all_gather(wg, "data", axis=1, tiled=True) \
            if "data" in mesh.shape else wg                     # FSDP gather
        wu = lax.all_gather(wu, "data", axis=1, tiled=True) \
            if "data" in mesh.shape else wu
        wd = lax.all_gather(wd, "data", axis=2, tiled=True) \
            if "data" in mesh.shape else wd
        h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
        h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
        y = jnp.einsum("ecf,efd->ecd", h, wd)                   # (e_loc, C*msz, d)
        y = lax.all_to_all(y, "model", split_axis=1, concat_axis=0,
                           tiled=True)                          # (e, C, d)
        y_pad = jnp.concatenate(
            [y, jnp.zeros((e, 1, d), y.dtype)], axis=1)
        out_rows = y_pad[flat_e, slot_c]                        # (t_sub*k, d)
        out_rows = out_rows * (weights.reshape(-1, 1)
                               * keep[:, None]).astype(out_rows.dtype)
        return out_rows.reshape(t_sub, k, d).sum(axis=1)

    router = p["router"].astype(x.dtype)
    wg = p["w_gate"].astype(x.dtype)
    wu = p["w_up"].astype(x.dtype)
    wd = p["w_down"].astype(x.dtype)
    fsdp = "data" if "data" in mesh.shape else None
    fn = jax.shard_map(
        body, mesh=mesh,
        in_specs=(rows_spec, P(None, None),
                  P("model", fsdp, None), P("model", fsdp, None),
                  P("model", None, fsdp)),
        out_specs=out_spec, check_vma=False)
    # Pin the flattened rows to the plain DP sharding before shard_map:
    # letting the 256-way row spec propagate backward through the merge
    # reshape poisons the layer-scan carry into full replication.
    rows_in = constrain(x.reshape(t, d), ("act_batch", None))
    out = fn(rows_in, router, wg, wu, wd)
    # Re-gather the model-axis row split BEFORE un-flattening: reshaping a
    # 256-way row-sharded (T, d) to (B, S, d) with B < 256 forces GSPMD
    # into involuntary full replication (measured: +25 GiB/device).
    out = constrain(out, ("act_batch", None))
    out = out.reshape(b, s, d)
    return constrain(out, ("act_batch", "act_seq", None))


def moe_layer_dense(p: dict, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """x: (B, S, d) -> (B, S, d).  SwiGLU experts, top-k token choice."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.experts_per_tok
    t = b * s
    xf = x.reshape(t, d)

    logits = xf @ p["router"].astype(xf.dtype)                 # (T, E)
    expert_idx, weights = route_topk(logits, k)                # (T, k)

    # Flatten (token, choice) rows and assign capacity slots.
    flat_e = expert_idx.reshape(t * k)                         # (T*k,)
    onehot = jax.nn.one_hot(flat_e, e, dtype=jnp.int32)        # (T*k, E)
    pos_in_expert = (jnp.cumsum(onehot, axis=0) - onehot)      # count before me
    slot = jnp.take_along_axis(pos_in_expert, flat_e[:, None], axis=1)[:, 0]

    capacity = int(cfg.capacity_factor * t * k / e)
    capacity = max(_round_up(capacity, 128), 128)              # MXU-friendly
    keep = slot < capacity
    # Dropped rows land on a per-expert scratch slot that is sliced away
    # (keeps the buffer's expert dim divisible for the EP shard).
    slot_c = jnp.where(keep, slot, capacity)

    xk = jnp.repeat(xf, k, axis=0)                             # (T*k, d)
    buf = jnp.zeros((e, capacity + 1, d), xf.dtype) \
        .at[flat_e, slot_c].add(xk)
    buf = buf[:, :capacity, :]
    buf = constrain(buf, ("act_experts", "act_expert_cap", None))  # EP shard

    # Expert SwiGLU (einsum over the expert-sharded buffer).
    wg = p["w_gate"].astype(buf.dtype)
    wu = p["w_up"].astype(buf.dtype)
    wd = p["w_down"].astype(buf.dtype)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, wg))
    h = h * jnp.einsum("ecd,edf->ecf", buf, wu)
    h = constrain(h, ("act_experts", "act_expert_cap", "act_ff"))
    out_buf = jnp.einsum("ecf,efd->ecd", h, wd)
    out_buf = constrain(out_buf, ("act_experts", "act_expert_cap", None))

    # Combine: gather each kept row back and weight it (scratch slot = 0).
    out_pad = jnp.concatenate(
        [out_buf, jnp.zeros((e, 1, d), out_buf.dtype)], axis=1)
    rows = out_pad[flat_e, slot_c]                             # (T*k, d)
    rows = rows * (weights.reshape(t * k, 1) * keep[:, None]).astype(rows.dtype)
    out = rows.reshape(t, k, d).sum(axis=1)
    out = constrain(out.reshape(b, s, d), ("act_batch", "act_seq", None))
    return out


def aux_load_balance_loss(router_logits: jax.Array, expert_idx: jax.Array,
                          n_experts: int, k: int) -> jax.Array:
    """Switch-style load-balance auxiliary loss (mean fraction * mean prob)."""
    probs = jax.nn.softmax(router_logits.astype(jnp.float32), axis=-1)
    frac = jnp.mean(
        jax.nn.one_hot(expert_idx, n_experts, dtype=jnp.float32), axis=(0, 1))
    return n_experts * jnp.sum(frac * probs.mean(axis=0))


def _round_up(x: int, m: int) -> int:
    return ((x + m - 1) // m) * m
