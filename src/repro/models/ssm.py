"""State-space (Mamba) layers, adapted for TPU.

Two variants, both lowered as *chunked* computations (HLO stays compact,
activation memory is O(S/L) checkpoints, and the heavy work is batched
matmul — what the MXU wants):

* Mamba1 (falcon-mamba): per-(channel,state) diagonal dynamics.  The
  recurrence runs as an outer ``lax.scan`` over chunks carrying the state
  with an inner ``associative_scan`` inside each (rematted) chunk.
* Mamba2 / SSD (zamba2): scalar-per-head decay, so the intra-chunk kernel
  collapses to dense (L×L) matmuls — the SSD "matmulization" is exactly
  the GPU-paper insight re-expressed as MXU-shaped einsums.

Each layer returns its final recurrent state so prefill can hand off to
O(1) decode steps.
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.arch import ArchConfig
from repro.models.layers import rms_norm
from repro.sharding.policy import constrain


class SSMState(NamedTuple):
    conv: jax.Array   # (B, d_conv-1, d_inner) rolling conv inputs
    h: jax.Array      # mamba1: (B, di, ds); mamba2: (B, nh, P, ds)


def _mask_dt(dt: jax.Array, mask: jax.Array | None) -> jax.Array:
    """Zero the step size at masked (pad) steps: ``dt == 0`` makes the
    recurrence an exact identity (decay ``exp(0·a) == 1``, input term
    ``dt·x·b == 0``), so a ragged chunk's pad tail never touches the
    carried state — the discipline chunked pad-free prefill relies on."""
    if mask is None:
        return dt
    return dt * mask.astype(dt.dtype)[..., None]


def _conv_state(prev: jax.Array | None, xin: jax.Array, k: int,
                fill: jax.Array | None) -> jax.Array:
    """Next rolling conv window: the last ``k−1`` *real* inputs.

    prev: (B, k−1, di) carry-in (zeros when None); xin: (B, S, di);
    ``fill`` (B,) counts the real (non-pad) inputs per row — pad rows sit
    at the tail, so the window is ``cat[fill : fill + k − 1]`` per row
    (the static ``fill == S`` slice when no ragged chunk is in play)."""
    bsz, s, di = xin.shape
    if prev is None:
        prev = jnp.zeros((bsz, k - 1, di), xin.dtype)
    cat = jnp.concatenate([prev.astype(xin.dtype), xin], axis=1)
    if fill is None:
        return lax.dynamic_slice_in_dim(cat, s, k - 1, axis=1)
    return jax.vmap(
        lambda row, n: lax.dynamic_slice_in_dim(row, n, k - 1, axis=0)
    )(cat, fill.astype(jnp.int32))


# ---------------------------------------------------------------------------
# Depthwise causal conv (k taps as shifts — no conv primitive needed)
# ---------------------------------------------------------------------------
def causal_conv(x: jax.Array, w: jax.Array, b: jax.Array,
                tail: jax.Array | None = None) -> jax.Array:
    """x: (B, S, di); w: (k, di); tail: (B, k-1, di) carry-in (or zeros)."""
    k = w.shape[0]
    if tail is None:
        tail = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(xp[:, j:j + x.shape[1], :] * w[j].astype(x.dtype)
              for j in range(k))
    return jax.nn.silu(out + b.astype(x.dtype))


# ---------------------------------------------------------------------------
# Mamba1 — chunked selective scan
# ---------------------------------------------------------------------------
def _ssm_scan_chunk(h0: jax.Array, decay: jax.Array, inp: jax.Array):
    """h[t] = decay[t] * h[t-1] + inp[t] within one chunk.

    decay/inp: (B, L, di, ds); h0: (B, di, ds).  Associative combine:
    (a2, b2) ∘ (a1, b1) = (a1·a2, b1·a2 + b2).
    """
    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    inp0 = inp.at[:, 0].add(decay[:, 0] * h0)
    a, b = lax.associative_scan(combine, (decay, inp0), axis=1)
    return b, b[:, -1]


def mamba1_layer(p: dict, x: jax.Array, cfg: ArchConfig,
                 state: SSMState | None = None, chunk: int = 128,
                 mask: jax.Array | None = None,
                 fill: jax.Array | None = None
                 ) -> Tuple[jax.Array, SSMState]:
    """x: (B, S, d_model) -> (y, final_state).

    ``mask`` (B, S) marks real steps (1) vs pad steps (0) and ``fill``
    (B,) counts the real steps per row — both optional, supplied by the
    chunked-prefill path so a ragged final chunk's pad tail leaves the
    recurrent and conv state exactly where the last real token put them.
    """
    bsz, s, _ = x.shape
    di, ds = cfg.d_inner, cfg.ssm_state
    xz = x @ p["in_proj"].astype(x.dtype)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = constrain(xin, ("act_batch", "act_seq", "act_dinner"))

    conv_tail = state.conv if state is not None else None
    xc = causal_conv(xin, p["conv_w"], p["conv_b"], conv_tail)
    new_conv = _conv_state(conv_tail, xin, cfg.d_conv, fill)

    dt_rank = p["x_dt"].shape[1]
    dt = jax.nn.softplus(
        (xc @ p["x_dt"].astype(xc.dtype)) @ p["dt_proj"].astype(xc.dtype)
        + p["dt_bias"].astype(xc.dtype))                       # (B,S,di)
    dt = _mask_dt(dt, mask)
    bmat = xc @ p["wb"].astype(xc.dtype)                       # (B,S,ds)
    cmat = xc @ p["wc"].astype(xc.dtype)                       # (B,S,ds)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))               # (di,ds)

    n_chunks = max(s // chunk, 1)
    chunk = s // n_chunks
    dt_c = dt.astype(jnp.float32).reshape(bsz, n_chunks, chunk, di)
    b_c = bmat.astype(jnp.float32).reshape(bsz, n_chunks, chunk, ds)
    c_c = cmat.reshape(bsz, n_chunks, chunk, ds)
    x_c = xc.astype(jnp.float32).reshape(bsz, n_chunks, chunk, di)

    h0 = (state.h if state is not None
          else jnp.zeros((bsz, di, ds), jnp.float32))

    @partial(jax.checkpoint, policy=jax.checkpoint_policies.nothing_saveable)
    def chunk_body(h, inputs):
        dtc, bc, cc, xcc = inputs                              # (B,L,·)
        decay = jnp.exp(dtc[..., None] * a)                    # (B,L,di,ds)
        inp = (dtc * xcc)[..., None] * bc[:, :, None, :]       # (B,L,di,ds)
        hs, h_last = _ssm_scan_chunk(h, decay, inp)
        y = jnp.einsum("blds,bls->bld", hs, cc.astype(jnp.float32))
        return h_last, y

    h_final, ys = lax.scan(
        chunk_body, h0,
        (jnp.moveaxis(dt_c, 1, 0), jnp.moveaxis(b_c, 1, 0),
         jnp.moveaxis(c_c.astype(jnp.float32), 1, 0),
         jnp.moveaxis(x_c, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, di)
    y = y + x_c.reshape(bsz, s, di) * p["d_skip"].astype(jnp.float32)
    y = (y.astype(x.dtype) * jax.nn.silu(z))
    y = constrain(y, ("act_batch", "act_seq", "act_dinner"))
    out = y @ p["out_proj"].astype(y.dtype)
    return out, SSMState(conv=new_conv, h=h_final)


def mamba1_decode(p: dict, x: jax.Array, cfg: ArchConfig,
                  state: SSMState) -> Tuple[jax.Array, SSMState]:
    """One step.  x: (B, 1, d_model)."""
    bsz = x.shape[0]
    di, ds, k = cfg.d_inner, cfg.ssm_state, cfg.d_conv
    xz = x @ p["in_proj"].astype(x.dtype)
    xin, z = jnp.split(xz, 2, axis=-1)                         # (B,1,di)
    window = jnp.concatenate([state.conv.astype(x.dtype), xin], axis=1)
    xc = jax.nn.silu(
        jnp.einsum("bkd,kd->bd", window, p["conv_w"].astype(x.dtype))
        + p["conv_b"].astype(x.dtype))[:, None, :]             # (B,1,di)
    new_conv = window[:, 1:, :]

    dt = jax.nn.softplus(
        (xc @ p["x_dt"].astype(xc.dtype)) @ p["dt_proj"].astype(xc.dtype)
        + p["dt_bias"].astype(xc.dtype)).astype(jnp.float32)   # (B,1,di)
    bmat = (xc @ p["wb"].astype(xc.dtype)).astype(jnp.float32)
    cmat = (xc @ p["wc"].astype(xc.dtype)).astype(jnp.float32)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    decay = jnp.exp(dt[:, 0, :, None] * a)                     # (B,di,ds)
    inp = (dt[:, 0, :] * xc.astype(jnp.float32)[:, 0, :])[..., None] \
        * bmat[:, 0, None, :]
    h = decay * state.h + inp
    y = jnp.einsum("bds,bs->bd", h, cmat[:, 0])[:, None, :]
    y = y + xc.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)
    y = y.astype(x.dtype) * jax.nn.silu(z)
    out = y @ p["out_proj"].astype(y.dtype)
    return out, SSMState(conv=new_conv, h=h)


# ---------------------------------------------------------------------------
# Mamba2 — SSD chunked matmul form
# ---------------------------------------------------------------------------
def mamba2_layer(p: dict, x: jax.Array, cfg: ArchConfig,
                 state: SSMState | None = None, chunk: int = 256,
                 mask: jax.Array | None = None,
                 fill: jax.Array | None = None
                 ) -> Tuple[jax.Array, SSMState]:
    """x: (B, S, d_model) -> (y, final_state).  Scalar decay per head.

    ``mask``/``fill`` as in ``mamba1_layer``: pad steps of a ragged
    prefill chunk are exact no-ops on the carried state."""
    bsz, s, _ = x.shape
    di, ds = cfg.d_inner, cfg.ssm_state
    nh = cfg.resolved_ssm_heads
    hp = di // nh

    xz = x @ p["in_proj"].astype(x.dtype)
    xin, z = jnp.split(xz, 2, axis=-1)
    xin = constrain(xin, ("act_batch", "act_seq", "act_dinner"))
    conv_tail = state.conv if state is not None else None
    xc = causal_conv(xin, p["conv_w"], p["conv_b"], conv_tail)
    new_conv = _conv_state(conv_tail, xin, cfg.d_conv, fill)

    bmat = (x @ p["wb"].astype(x.dtype)).astype(jnp.float32)   # (B,S,ds)
    cmat = (x @ p["wc"].astype(x.dtype)).astype(jnp.float32)
    dt = jax.nn.softplus(
        (x @ p["dt_w"].astype(x.dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                    # (B,S,nh)
    dt = _mask_dt(dt, mask)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))               # (nh,)

    n_chunks = max(s // chunk, 1)
    chunk = s // n_chunks
    xh = xc.astype(jnp.float32).reshape(bsz, n_chunks, chunk, nh, hp)
    dt_c = dt.reshape(bsz, n_chunks, chunk, nh)
    b_c = bmat.reshape(bsz, n_chunks, chunk, ds)
    c_c = cmat.reshape(bsz, n_chunks, chunk, ds)

    seg = dt_c * a                                             # (B,n,L,nh)
    l_cum = jnp.cumsum(seg, axis=2)                            # inclusive
    # --- diagonal (intra-chunk) block: dense L×L matmuls ---
    g = jnp.einsum("bnls,bnms->bnlm", c_c, b_c)                # (B,n,L,L)
    rel = l_cum[:, :, :, None, :] - l_cum[:, :, None, :, :]    # (B,n,L,L,nh)
    tril = jnp.tril(jnp.ones((chunk, chunk), bool))
    decay = jnp.where(tril[None, None, :, :, None], jnp.exp(rel), 0.0)
    att = g[..., None] * decay * dt_c[:, :, None, :, :]        # (B,n,L,L,nh)
    y_diag = jnp.einsum("bnlsh,bnshp->bnlhp", att, xh)

    # --- chunk summary states + inter-chunk scan ---
    decay_last = jnp.exp(l_cum[:, :, -1:, :] - l_cum)          # (B,n,L,nh)
    xw = xh * (dt_c * decay_last)[..., None]                   # (B,n,L,nh,P)
    s_c = jnp.einsum("bnlhp,bnls->bnhps", xw, b_c)             # (B,n,nh,P,ds)
    chunk_decay = jnp.exp(seg.sum(axis=2))                     # (B,n,nh)

    h0 = (state.h if state is not None
          else jnp.zeros((bsz, nh, hp, ds), jnp.float32))

    def inter(h, inputs):
        sc, cd = inputs                                        # per chunk
        h_new = cd[..., None, None] * h + sc
        return h_new, h                                        # emit h_prev

    h_final, h_prevs = lax.scan(
        inter, h0, (jnp.moveaxis(s_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)))
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)                      # (B,n,nh,P,ds)
    y_inter = jnp.einsum("bnls,bnhps->bnlhp", c_c, h_prevs) \
        * jnp.exp(l_cum)[..., None]
    y = (y_diag + y_inter).reshape(bsz, s, nh, hp)
    y = y + xh.reshape(bsz, s, nh, hp) * p["d_skip"].astype(jnp.float32)[:, None]
    y = y.reshape(bsz, s, di).astype(x.dtype) * jax.nn.silu(z)
    y = rms_norm(p["gate_norm"], y, cfg.norm_eps)
    y = constrain(y, ("act_batch", "act_seq", "act_dinner"))
    return y @ p["out_proj"].astype(y.dtype), SSMState(new_conv, h_final)


def mamba2_decode(p: dict, x: jax.Array, cfg: ArchConfig,
                  state: SSMState) -> Tuple[jax.Array, SSMState]:
    """One step.  x: (B, 1, d_model)."""
    bsz = x.shape[0]
    di, ds = cfg.d_inner, cfg.ssm_state
    nh = cfg.resolved_ssm_heads
    hp = di // nh
    xz = x @ p["in_proj"].astype(x.dtype)
    xin, z = jnp.split(xz, 2, axis=-1)
    window = jnp.concatenate([state.conv.astype(x.dtype), xin], axis=1)
    xc = jax.nn.silu(
        jnp.einsum("bkd,kd->bd", window, p["conv_w"].astype(x.dtype))
        + p["conv_b"].astype(x.dtype))                         # (B,di)
    new_conv = window[:, 1:, :]

    bmat = (x[:, 0] @ p["wb"].astype(x.dtype)).astype(jnp.float32)
    cmat = (x[:, 0] @ p["wc"].astype(x.dtype)).astype(jnp.float32)
    dt = jax.nn.softplus(
        (x[:, 0] @ p["dt_w"].astype(x.dtype)).astype(jnp.float32)
        + p["dt_bias"].astype(jnp.float32))                    # (B,nh)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    xf = xc.astype(jnp.float32).reshape(bsz, nh, hp)
    decay = jnp.exp(dt * a)                                    # (B,nh)
    inp = jnp.einsum("bhp,bs->bhps", xf * dt[..., None], bmat)
    h = decay[..., None, None] * state.h + inp
    y = jnp.einsum("bhps,bs->bhp", h, cmat)
    y = y + xf * p["d_skip"].astype(jnp.float32)[:, None]
    y = y.reshape(bsz, 1, di).astype(x.dtype) * jax.nn.silu(z)
    y = rms_norm(p["gate_norm"], y, cfg.norm_eps)
    return y @ p["out_proj"].astype(y.dtype), SSMState(new_conv, h)
