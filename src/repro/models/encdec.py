"""Encoder-decoder backbone (seamless-m4t-large-v2).

The audio frontend is a STUB per the assignment: ``input_specs()``
supplies precomputed frame embeddings (B, S_enc, d).  The transformer
backbone is real: a bidirectional encoder and a causal decoder with
cross-attention, both scanned over stacked layer params.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.arch import ArchConfig
from repro.core.quantize import PrecisionPolicy, maybe_quant_kv
from repro.kernels.ops import quant_matmul
from repro.models.layers import (attention_chunk_layer,
                                 attention_decode_layer, attention_layer,
                                 rms_norm, swiglu_mlp)
from repro.models.transformer import (_maybe_remat, _write_pos,
                                      _write_pos_chunk, default_positions,
                                      embed_tokens, lm_loss,
                                      maybe_cast_params, unembed)
from repro.sharding.policy import constrain


def _attn_kwargs(cfg: ArchConfig):
    return dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_variant=cfg.rope_variant,
                rope_theta=cfg.rope_theta, mrope_sections=cfg.mrope_sections)


def encode(cfg: ArchConfig, params, enc_embeddings: jax.Array, *,
           remat: str = "none",
           policy: Optional[PrecisionPolicy] = None) -> jax.Array:
    """Bidirectional encoder over frame embeddings (B, S_enc, d)."""
    x = enc_embeddings.astype(cfg.activation_dtype)
    x = constrain(x, ("act_batch", "act_res_seq", "act_dmodel"))
    b, s = x.shape[:2]
    positions = default_positions(cfg, b, s)

    def body(h, p):
        hh = rms_norm(p["attn_norm"], h, cfg.norm_eps)
        attn_out, _ = attention_layer(p["attn"], hh, positions,
                                      causal=False, policy=policy,
                                      **_attn_kwargs(cfg))
        h = h + attn_out
        hh = rms_norm(p["mlp_norm"], h, cfg.norm_eps)
        h = h + swiglu_mlp(p["mlp"], hh, policy)
        return constrain(h, ("act_batch", "act_res_seq", "act_dmodel")), None

    x, _ = lax.scan(_maybe_remat(body, remat), x, params["enc_blocks"])
    return rms_norm(params["enc_final_norm"], x, cfg.norm_eps)


def _decoder_body(cfg: ArchConfig, enc_out, enc_positions, positions,
                  collect_kv: bool,
                  policy: Optional[PrecisionPolicy] = None):
    def body(h, p):
        hh = rms_norm(p["attn_norm"], h, cfg.norm_eps)
        attn_out, kv = attention_layer(p["attn"], hh, positions,
                                       policy=policy, **_attn_kwargs(cfg))
        h = h + attn_out
        # cross attention: K/V from encoder output, no rope on keys
        hh = rms_norm(p["xattn_norm"], h, cfg.norm_eps)
        xk = quant_matmul(enc_out, p["xattn"]["wk"], policy=policy).reshape(
            *enc_out.shape[:2], cfg.n_kv_heads, cfg.resolved_head_dim)
        xv = quant_matmul(enc_out, p["xattn"]["wv"], policy=policy).reshape(
            *enc_out.shape[:2], cfg.n_kv_heads, cfg.resolved_head_dim)
        kw = dict(_attn_kwargs(cfg))
        kw["rope_variant"] = "none"
        x_out, _ = attention_layer(p["xattn"], hh, positions, causal=False,
                                   kv_override=(xk, xv),
                                   kv_positions=enc_positions, policy=policy,
                                   **kw)
        h = h + x_out
        hh = rms_norm(p["mlp_norm"], h, cfg.norm_eps)
        h = h + swiglu_mlp(p["mlp"], hh, policy)
        h = constrain(h, ("act_batch", "act_res_seq", "act_dmodel"))
        return h, (kv, (xk, xv)) if collect_kv else None
    return body


def forward_train(cfg: ArchConfig, params, inputs: Dict[str, jax.Array], *,
                  remat: str = "full",
                  policy: Optional[PrecisionPolicy] = None):
    """inputs: enc_embeddings (B, S_enc, d), tokens (B, S), labels (B, S)."""
    params = maybe_cast_params(params, cfg)
    enc_out = encode(cfg, params, inputs["enc_embeddings"], remat=remat,
                     policy=policy)
    tokens = inputs["tokens"]
    b, s = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    positions = default_positions(cfg, b, s)
    enc_positions = default_positions(cfg, b, enc_out.shape[1])
    body = _decoder_body(cfg, enc_out, enc_positions, positions, False,
                         policy=policy)
    x, _ = lax.scan(_maybe_remat(body, remat), x, params["blocks"])
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params, x, cfg)
    return lm_loss(logits, inputs["labels"], cfg.vocab_size)


def forward_prefill(cfg: ArchConfig, params, inputs: Dict[str, jax.Array],
                    policy: Optional[PrecisionPolicy] = None):
    """Prefill the decoder self-attn cache + precompute cross-attn KV."""
    params = maybe_cast_params(params, cfg)
    enc_out = encode(cfg, params, inputs["enc_embeddings"], policy=policy)
    tokens = inputs["tokens"]
    b, s = tokens.shape
    x = embed_tokens(params, tokens, cfg)
    positions = default_positions(cfg, b, s)
    enc_positions = default_positions(cfg, b, enc_out.shape[1])
    body = _decoder_body(cfg, enc_out, enc_positions, positions, True,
                         policy=policy)
    x, kvs = lax.scan(body, x, params["blocks"])
    (k, v), (xk, xv) = kvs
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params, x[:, -1:, :], cfg)[:, 0]
    from repro.models.transformer import _constrain_kv_cache
    cache = {"k": _constrain_kv_cache(k), "v": _constrain_kv_cache(v),
             "xk": _constrain_kv_cache(xk), "xv": _constrain_kv_cache(xv),
             "full_pos": positions,
             "enc_pos": enc_positions}
    if policy is not None and policy.kv_cache == "int8":
        for key in ("k", "v", "xk", "xv"):
            cache[key] = maybe_quant_kv(policy, cache[key])
    return logits, cache


def forward_decode(cfg: ArchConfig, params, cache, token: jax.Array,
                   position: jax.Array, write_idx=None,
                   policy: Optional[PrecisionPolicy] = None,
                   kv_len=None, block_table=None):
    """``kv_len`` bounds the decoder self-attn cache rows (serving
    contract, see transformer.forward_decode; ``kv_len == 0`` rows also
    suppress their cache writes); cross-attn KV is the fixed-length
    encoder output and is never bounded.

    ``block_table`` is accepted for ``ModelFns`` signature parity but
    enc-dec caches are not paged (the serving engines reject enc-dec
    archs at construction — a modality runner owns the encoder pass)."""
    if block_table is not None:
        raise NotImplementedError("enc-dec decode caches are not paged")
    params = maybe_cast_params(params, cfg)
    x = embed_tokens(params, token[:, None], cfg)
    widx = position if write_idx is None else write_idx
    active = None if kv_len is None else kv_len > 0

    def body(h, pc):
        p, ck, cv, xk, xv = pc
        hh = rms_norm(p["attn_norm"], h, cfg.norm_eps)
        attn_out, ck, cv, _ = attention_decode_layer(
            p["attn"], hh, position, ck, cv, cache["full_pos"], widx,
            policy=policy, kv_len=kv_len, active=active,
            **_attn_kwargs(cfg))
        h = h + attn_out
        hh = rms_norm(p["xattn_norm"], h, cfg.norm_eps)
        x_out, _, _, _ = attention_decode_layer(
            p["xattn"], hh, position, xk, xv, cache["enc_pos"], position,
            cross=True, policy=policy, **_attn_kwargs(cfg))
        h = h + x_out
        hh = rms_norm(p["mlp_norm"], h, cfg.norm_eps)
        h = h + swiglu_mlp(p["mlp"], hh, policy)
        return h, (ck, cv)

    x, (ks, vs) = lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params, x, cfg)[:, 0]
    new_cache = dict(cache, k=ks, v=vs)
    new_cache["full_pos"] = _write_pos(cache["full_pos"], position, widx,
                                       active)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Chunked pad-free prefill (decoder side; the encoder always runs once)
# ---------------------------------------------------------------------------
def init_chunk_cache(cfg: ArchConfig, params, enc_embeddings: jax.Array,
                     capacity: int,
                     policy: Optional[PrecisionPolicy] = None):
    """Empty decoder cache of ``capacity`` rows with the cross-attn KV
    precomputed: run the encoder once, project its output, and leave the
    self-attn K/V zeroed with positions −1 (invalid).  The starting
    point for ``forward_prefill_chunk``."""
    params = maybe_cast_params(params, cfg)
    enc_out = encode(cfg, params, enc_embeddings, policy=policy)
    b, s_enc = enc_out.shape[:2]
    hd = cfg.resolved_head_dim

    def project(p):
        xk = quant_matmul(enc_out, p["xattn"]["wk"], policy=policy).reshape(
            b, s_enc, cfg.n_kv_heads, hd)
        xv = quant_matmul(enc_out, p["xattn"]["wv"], policy=policy).reshape(
            b, s_enc, cfg.n_kv_heads, hd)
        return xk, xv

    _, (xks, xvs) = lax.scan(lambda c, p: (c, project(p)), None,
                             params["blocks"])
    n_layers = jax.tree.leaves(params["blocks"])[0].shape[0]
    kv = jnp.zeros((n_layers, b, capacity, cfg.n_kv_heads, hd),
                   cfg.activation_dtype)
    cache = {"k": kv, "v": kv,
             "xk": xks, "xv": xvs,
             "full_pos": jnp.full((b, capacity), -1, jnp.int32),
             "enc_pos": default_positions(cfg, b, s_enc)}
    if policy is not None and policy.kv_cache == "int8":
        for key in ("k", "v", "xk", "xv"):
            cache[key] = maybe_quant_kv(policy, cache[key])
    return cache


def forward_prefill_chunk(cfg: ArchConfig, params, cache,
                          tokens: jax.Array, positions: jax.Array,
                          policy: Optional[PrecisionPolicy] = None,
                          kv_len=None, block_table=None):
    """One decoder prefill chunk against a live cache built by
    ``init_chunk_cache`` (see transformer.forward_prefill_chunk for the
    chunk contract): self-attention writes the chunk unpadded and
    attends the live prefix; cross-attention reads the fixed encoder KV.
    ``block_table`` is signature parity only — enc-dec caches are not
    paged (see ``forward_decode``).
    """
    if block_table is not None:
        raise NotImplementedError("enc-dec decode caches are not paged")
    params = maybe_cast_params(params, cfg)
    x = embed_tokens(params, tokens, cfg)
    write_full = positions[:, 0]

    def body(h, pc):
        p, ck, cv, xk, xv = pc
        hh = rms_norm(p["attn_norm"], h, cfg.norm_eps)
        attn_out, ck, cv, _ = attention_chunk_layer(
            p["attn"], hh, positions, ck, cv, cache["full_pos"], write_full,
            policy=policy, kv_len=kv_len, **_attn_kwargs(cfg))
        h = h + attn_out
        hh = rms_norm(p["xattn_norm"], h, cfg.norm_eps)
        # cross attention: no rope on the queries (matches the one-shot
        # prefill's kv_override path and the decode cross branch)
        xkw = dict(_attn_kwargs(cfg))
        xkw["rope_variant"] = "none"
        x_out, _, _, _ = attention_chunk_layer(
            p["xattn"], hh, positions, xk, xv, cache["enc_pos"], write_full,
            cross=True, policy=policy, **xkw)
        h = h + x_out
        hh = rms_norm(p["mlp_norm"], h, cfg.norm_eps)
        h = h + swiglu_mlp(p["mlp"], hh, policy)
        return h, (ck, cv)

    x, (ks, vs) = lax.scan(
        body, x, (params["blocks"], cache["k"], cache["v"],
                  cache["xk"], cache["xv"]))
    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    logits = unembed(params, x, cfg)
    new_cache = dict(cache, k=ks, v=vs)
    new_cache["full_pos"] = _write_pos_chunk(cache["full_pos"], positions,
                                             write_full)
    return logits, new_cache
