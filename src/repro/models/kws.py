"""The paper's evaluation models (§5.1, MLPerf Tiny tasks), in pure JAX:

* DS-CNN  — depthwise-separable CNN for keyword spotting (Sørensen 2020),
* MobileNetV1-0.25 — visual wake words binary classifier,
* CIFAR CNN — small convnet for image classification,
* conv1d stacks — the EON-Tuner search family from Table 3
  ("Nx conv1d (a to b)": N conv1d blocks widening a→b).

Plain param-dict style matching the rest of the framework; convs via
``lax.conv_general_dilated`` (NHWC).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------
def conv2d(x, w, stride=1, groups=1, padding="SAME"):
    return lax.conv_general_dilated(
        x, w, window_strides=(stride, stride), padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=groups)


def conv1d(x, w, stride=1, padding="SAME"):
    return lax.conv_general_dilated(
        x, w, window_strides=(stride,), padding=padding,
        dimension_numbers=("NWC", "WIO", "NWC"))


def batchnorm_apply(p, x):
    # inference-style: folded scale/offset (trained via simple moving stats)
    return x * p["scale"] + p["offset"]


def _conv_init(key, shape):
    fan_in = math.prod(shape[:-1])
    return jax.random.normal(key, shape, jnp.float32) * (2.0 / fan_in) ** 0.5


def _bn_init(c):
    return {"scale": jnp.ones((c,), jnp.float32),
            "offset": jnp.zeros((c,), jnp.float32)}


def _dense_init(key, din, dout):
    return {"w": jax.random.normal(key, (din, dout), jnp.float32)
            * (1.0 / din) ** 0.5,
            "b": jnp.zeros((dout,), jnp.float32)}


# ---------------------------------------------------------------------------
# DS-CNN (KWS)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class DSCNNConfig:
    n_classes: int = 12
    n_filters: int = 64
    n_blocks: int = 4
    name: str = "ds-cnn"


def dscnn_init(cfg: DSCNNConfig, key, input_shape: Tuple[int, int]):
    keys = jax.random.split(key, 2 + 2 * cfg.n_blocks)
    f = cfg.n_filters
    params: Dict = {
        "stem": {"w": _conv_init(keys[0], (10, 4, 1, f)), "bn": _bn_init(f)},
        "blocks": [],
        "head": _dense_init(keys[1], f, cfg.n_classes),
    }
    for i in range(cfg.n_blocks):
        params["blocks"].append({
            "dw": {"w": _conv_init(keys[2 + 2 * i], (3, 3, 1, f)),
                   "bn": _bn_init(f)},
            "pw": {"w": _conv_init(keys[3 + 2 * i], (1, 1, f, f)),
                   "bn": _bn_init(f)},
        })
    return params


def dscnn_apply(cfg: DSCNNConfig, params, feats: jax.Array) -> jax.Array:
    """feats: (B, n_frames, n_mels) -> logits (B, n_classes)."""
    x = feats[..., None]                                   # NHWC
    x = conv2d(x, params["stem"]["w"], stride=2)
    x = jax.nn.relu(batchnorm_apply(params["stem"]["bn"], x))
    for blk in params["blocks"]:
        c = x.shape[-1]
        x = conv2d(x, blk["dw"]["w"], groups=c)
        x = jax.nn.relu(batchnorm_apply(blk["dw"]["bn"], x))
        x = conv2d(x, blk["pw"]["w"])
        x = jax.nn.relu(batchnorm_apply(blk["pw"]["bn"], x))
    x = x.mean(axis=(1, 2))                                # global avg pool
    return x @ params["head"]["w"] + params["head"]["b"]


# ---------------------------------------------------------------------------
# MobileNetV1 (VWW)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class MobileNetV1Config:
    n_classes: int = 2
    width_mult: float = 0.25
    name: str = "mobilenetv1"


_MBV1_PLAN = [  # (out_channels@1.0, stride)
    (64, 1), (128, 2), (128, 1), (256, 2), (256, 1), (512, 2),
    (512, 1), (512, 1), (512, 1), (512, 1), (512, 1), (1024, 2), (1024, 1),
]


def mobilenetv1_init(cfg: MobileNetV1Config, key,
                     input_shape: Tuple[int, int, int] = (96, 96, 3)):
    wm = cfg.width_mult
    c_in = max(int(32 * wm), 8)
    keys = jax.random.split(key, 2 + 2 * len(_MBV1_PLAN))
    params: Dict = {
        "stem": {"w": _conv_init(keys[0], (3, 3, input_shape[2], c_in)),
                 "bn": _bn_init(c_in)},
        "blocks": [],
    }
    c = c_in
    for i, (c_out_base, stride) in enumerate(_MBV1_PLAN):
        c_out = max(int(c_out_base * wm), 8)
        params["blocks"].append({
            "dw": {"w": _conv_init(keys[1 + 2 * i], (3, 3, 1, c)),
                   "bn": _bn_init(c)},
            "pw": {"w": _conv_init(keys[2 + 2 * i], (1, 1, c, c_out)),
                   "bn": _bn_init(c_out)},
        })
        c = c_out
    params["head"] = _dense_init(keys[-1], c, cfg.n_classes)
    return params


def mobilenetv1_apply(cfg: MobileNetV1Config, params, images) -> jax.Array:
    x = conv2d(images, params["stem"]["w"], stride=2)
    x = jax.nn.relu(batchnorm_apply(params["stem"]["bn"], x))
    for blk, (_, stride) in zip(params["blocks"], _MBV1_PLAN):
        cdim = x.shape[-1]
        x = conv2d(x, blk["dw"]["w"], stride=stride, groups=cdim)
        x = jax.nn.relu(batchnorm_apply(blk["dw"]["bn"], x))
        x = conv2d(x, blk["pw"]["w"])
        x = jax.nn.relu(batchnorm_apply(blk["pw"]["bn"], x))
    x = x.mean(axis=(1, 2))
    return x @ params["head"]["w"] + params["head"]["b"]


# ---------------------------------------------------------------------------
# CIFAR CNN (image classification)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class CifarCNNConfig:
    n_classes: int = 10
    name: str = "cifar-cnn"


def cifar_cnn_init(cfg: CifarCNNConfig, key,
                   input_shape: Tuple[int, int, int] = (32, 32, 3)):
    keys = jax.random.split(key, 4)
    return {
        "c1": {"w": _conv_init(keys[0], (3, 3, input_shape[2], 32)),
               "bn": _bn_init(32)},
        "c2": {"w": _conv_init(keys[1], (3, 3, 32, 64)), "bn": _bn_init(64)},
        "c3": {"w": _conv_init(keys[2], (3, 3, 64, 64)), "bn": _bn_init(64)},
        "head": _dense_init(keys[3], 64, cfg.n_classes),
    }


def cifar_cnn_apply(cfg: CifarCNNConfig, params, images) -> jax.Array:
    x = images
    for name in ("c1", "c2", "c3"):
        x = conv2d(x, params[name]["w"])
        x = jax.nn.relu(batchnorm_apply(params[name]["bn"], x))
        x = lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1),
                              (1, 2, 2, 1), "VALID")
    x = x.mean(axis=(1, 2))
    return x @ params["head"]["w"] + params["head"]["b"]


# ---------------------------------------------------------------------------
# conv1d stacks — the EON-Tuner Table 3 model family
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Conv1DStackConfig:
    """"Nx conv1d (a to b)": N blocks, channels geometric from a to b."""
    n_classes: int = 12
    n_blocks: int = 4
    ch_first: int = 32
    ch_last: int = 256
    kernel: int = 3
    name: str = "conv1d-stack"

    @property
    def channels(self) -> List[int]:
        if self.n_blocks == 1:
            return [self.ch_last]
        r = (self.ch_last / self.ch_first) ** (1.0 / (self.n_blocks - 1))
        return [int(round(self.ch_first * r ** i))
                for i in range(self.n_blocks)]


def conv1d_stack_init(cfg: Conv1DStackConfig, key,
                      input_shape: Tuple[int, int]):
    keys = jax.random.split(key, cfg.n_blocks + 1)
    chans = cfg.channels
    params: Dict = {"blocks": [], "head": None}
    c = input_shape[1]
    for i, c_out in enumerate(chans):
        params["blocks"].append(
            {"w": _conv_init(keys[i], (cfg.kernel, c, c_out)),
             "bn": _bn_init(c_out)})
        c = c_out
    params["head"] = _dense_init(keys[-1], c, cfg.n_classes)
    return params


def conv1d_stack_apply(cfg: Conv1DStackConfig, params, feats) -> jax.Array:
    """feats: (B, n_frames, n_feat) -> (B, n_classes)."""
    x = feats
    for blk in params["blocks"]:
        x = conv1d(x, blk["w"])
        x = jax.nn.relu(batchnorm_apply(blk["bn"], x))
        x = lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 1), (1, 2, 1),
                              "VALID")
    x = x.mean(axis=1)
    return x @ params["head"]["w"] + params["head"]["b"]


def count_params(params) -> int:
    return sum(int(p.size) for p in jax.tree.leaves(params))


def model_macs_conv1d(cfg: Conv1DStackConfig, input_shape) -> int:
    """Analytic MACs for the estimator (paper §4.4)."""
    frames, feat = input_shape
    macs, c, f = 0, feat, frames
    for c_out in cfg.channels:
        macs += f * cfg.kernel * c * c_out
        f = max(f // 2, 1)
        c = c_out
    macs += c * cfg.n_classes
    return macs
