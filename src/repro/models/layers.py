"""Foundational layers: norms, rotary embeddings, attention variants, MLP.

All functions are pure: ``f(params, x, ...) -> y``.  Activation sharding is
expressed through logical-axis constraints (``sharding.policy.constrain``)
so the same model code runs unsharded on CPU and fully sharded on a pod.

Attention comes in three structurally different lowerings (chosen
statically per layer/shape so the HLO is honest about FLOPs and memory):

* ``full_attention``     — plain O(S^2) scores; short sequences.
* ``chunked_attention``  — ``lax.scan`` over KV chunks with online softmax
                           (flash-attention schedule in jnp); long sequences.
* ``local_attention``    — sliding-window via the two-chunk band trick;
                           O(S * 2W) FLOPs, no scan carry.
* decode attention       — one query step against the slot-addressed KV
                           cache, dispatched through
                           ``kernels.ops.decode_attention``: the Pallas
                           flash-decode kernel on TPU (per-slot kv_len
                           bounding, in-tile Int8KV dequant), the jnp
                           grouped-q einsum ref elsewhere.
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import flags
from repro.core.quantize import (Int8KV, PrecisionPolicy, dequant_kv,
                                 quant_kv)
from repro.kernels.ops import chunk_attention, decode_attention, quant_matmul
from repro.sharding.policy import constrain

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# KV-cache representation helpers (PrecisionPolicy, serving tier)
# ---------------------------------------------------------------------------
def _constrain_decode_kv(cache):
    if isinstance(cache, Int8KV):
        return Int8KV(
            constrain(cache.q, ("act_batch", "act_cache_seq",
                                "act_kv_heads", None)),
            constrain(cache.scale, ("act_batch", "act_cache_seq",
                                    "act_kv_heads")))
    return constrain(cache, ("act_batch", "act_cache_seq",
                             "act_kv_heads", None))


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def rms_norm(scale: jax.Array, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings (RoPE + M-RoPE)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """Inverse frequencies, shape (head_dim // 2,), float32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Standard RoPE. x: (..., S, H, D); positions: broadcastable to (..., S)."""
    freqs = rope_freqs(x.shape[-1], theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., S, D/2)
    angles = angles[..., None, :]                                # (..., S, 1, D/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Tuple[int, int, int]) -> jax.Array:
    """Multimodal RoPE (Qwen2-VL): head_dim/2 frequencies split into
    (temporal, height, width) sections, each rotated by its own position
    stream.  positions: (..., S, 3)."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)                                  # (D/2,)
    assert sum(sections) == d // 2, (sections, d)
    # Build per-frequency position selection: section i uses positions[..., i].
    sec_ids = jnp.repeat(jnp.arange(3), jnp.array(sections),
                         total_repeat_length=d // 2)              # (D/2,)
    pos = jnp.take_along_axis(
        positions.astype(jnp.float32),
        jnp.broadcast_to(sec_ids, positions.shape[:-1] + (d // 2,)).astype(jnp.int32),
        axis=-1)                                                  # (..., S, D/2)
    angles = (pos * freqs)[..., None, :]                          # (..., S, 1, D/2)
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def position_encode(q: jax.Array, k: jax.Array, positions: jax.Array,
                    variant: str, theta: float,
                    sections: Tuple[int, int, int]) -> Tuple[jax.Array, jax.Array]:
    if variant == "mrope":
        return (apply_mrope(q, positions, theta, sections),
                apply_mrope(k, positions, theta, sections))
    if variant == "rope":
        return (apply_rope(q, positions, theta),
                apply_rope(k, positions, theta))
    if variant == "none":
        return q, k
    raise ValueError(f"unknown rope variant {variant!r}")


# ---------------------------------------------------------------------------
# Attention cores
# ---------------------------------------------------------------------------
def _repeat_kv(kv: jax.Array, hq: int, axis: int = 2) -> jax.Array:
    """Broadcast KV heads up to Hq.  A reshape of the *query* head dim
    into (Hkv, group) would split a model-axis-sharded dimension into
    factors GSPMD can only partially shard (measured: full-replication
    bailouts → 16x attention flops); repeating the (replicated or
    cleanly-sharded) KV heads keeps the einsum dims 1:1 with shardings.
    """
    hkv = kv.shape[axis]
    if hkv == hq:
        return kv
    return jnp.repeat(kv, hq // hkv, axis=axis)


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B, Sq, Hq, D); k: (B, Sk, Hkv, D) -> (B, Hq, Sq, Sk)."""
    k = _repeat_kv(k, q.shape[2])
    return jnp.einsum("bqhd,bkhd->bhqk", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_combine(p: jax.Array, v: jax.Array) -> jax.Array:
    """p: (B, Hq, Sq, Sk); v: (B, Sk, Hkv, D) -> (B, Sq, Hq, D)."""
    v = _repeat_kv(v, p.shape[1])
    return jnp.einsum("bhqk,bkhd->bqhd", p, v.astype(p.dtype))


def full_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                   q_positions: jax.Array, k_positions: jax.Array,
                   window: int = 0, causal: bool = True) -> jax.Array:
    """Plain attention with optional causal / sliding-window masking.

    positions are (B, S) absolute indices (mask is position-based so the
    same code serves packed/shifted sequences and cache decoding).
    Negative key positions mark invalid entries and are never attended.
    """
    scale = q.shape[-1] ** -0.5
    scores = _gqa_scores(q * scale, k)                       # (B,Hq,Sq,Sk) f32
    qp = q_positions[:, None, :, None]
    kp = k_positions[:, None, None, :]
    mask = kp >= 0
    if causal:
        mask = jnp.logical_and(mask, kp <= qp)
    if window > 0:
        mask = jnp.logical_and(mask, kp > qp - window)
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    return _gqa_combine(p.astype(v.dtype), v)


def chunked_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                      q_positions: jax.Array, k_positions: jax.Array,
                      chunk: int = 1024, causal: bool = True) -> jax.Array:
    """Online-softmax attention: ``lax.scan`` over KV chunks.

    The flash-attention schedule expressed in jnp: memory is
    O(Sq * chunk) instead of O(Sq * Sk); this is the ref/HLO twin of
    ``kernels/flash_attention.py``.
    """
    b, sq, hq, d = q.shape
    sk = k.shape[1]
    assert sk % chunk == 0, (sk, chunk)
    n_chunks = sk // chunk
    scale = d ** -0.5
    qs = (q * scale).astype(jnp.float32)

    k_c = k.reshape(b, n_chunks, chunk, *k.shape[2:])
    v_c = v.reshape(b, n_chunks, chunk, *v.shape[2:])
    kp_c = k_positions.reshape(b, n_chunks, chunk)
    # scan carries: (acc (B,Sq,Hq,D) f32, row max m, row sum l) per query.
    acc0 = jnp.zeros((b, sq, hq, d), jnp.float32)
    m0 = jnp.full((b, hq, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, hq, sq), jnp.float32)

    def body(carry, inputs):
        acc, m, l = carry
        kc, vc, kpc = inputs                                   # chunk leaves
        s = _gqa_scores(qs, kc)                                # (B,Hq,Sq,C)
        qp = q_positions[:, None, :, None]
        kp = kpc[:, None, None, :]
        mask = kp >= 0
        if causal:
            mask = jnp.logical_and(mask, kp <= qp)
        s = jnp.where(mask, s, NEG_INF)
        m_new = jnp.maximum(m, s.max(axis=-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(s - m_new[..., None])
        l_new = l * alpha + p.sum(axis=-1)
        if flags.get("bf16_attn_p"):
            # flash-style: p consumed in bf16 by the MXU, f32 accumulate
            pv = _gqa_combine(p.astype(v.dtype), vc).astype(jnp.float32)
        else:
            pv = _gqa_combine(p, vc.astype(jnp.float32))       # (B,Sq,Hq,D)
        acc_new = acc * alpha.transpose(0, 2, 1)[..., None] + pv
        return (acc_new, m_new, l_new), None

    (acc, m, l), _ = lax.scan(
        body, (acc0, m0, l0),
        (jnp.moveaxis(k_c, 1, 0), jnp.moveaxis(v_c, 1, 0),
         jnp.moveaxis(kp_c, 1, 0)))
    out = acc / jnp.maximum(l.transpose(0, 2, 1)[..., None], 1e-30)
    return out.astype(v.dtype)


def local_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    q_positions: jax.Array, k_positions: jax.Array,
                    window: int) -> jax.Array:
    """Sliding-window attention via the two-chunk band trick.

    With chunk length C == window, query chunk i can only see key chunks
    i-1 and i, so the banded score tensor is (B, H, nC, C, 2C):
    O(S * 2W) FLOPs — honest sub-quadratic HLO for gemma3-style local
    layers (vs masking a full S^2 tensor).
    """
    b, s, hq, d = q.shape
    c = window
    assert s % c == 0, (s, c)
    n = s // c
    scale = d ** -0.5
    qc = (q * scale).reshape(b, n, c, hq, d)
    kc = k.reshape(b, n, c, *k.shape[2:])
    vc = v.reshape(b, n, c, *v.shape[2:])
    # previous chunk (zeros for the first chunk — masked out by positions)
    kprev = jnp.concatenate([jnp.zeros_like(kc[:, :1]), kc[:, :-1]], axis=1)
    vprev = jnp.concatenate([jnp.zeros_like(vc[:, :1]), vc[:, :-1]], axis=1)
    kb = jnp.concatenate([kprev, kc], axis=2)                  # (B,n,2C,Hkv,D)
    vb = jnp.concatenate([vprev, vc], axis=2)

    qp = q_positions.reshape(b, n, c)
    kp = k_positions.reshape(b, n, c)
    kp_prev = jnp.concatenate(
        [jnp.full_like(kp[:, :1], -(10 ** 9)), kp[:, :-1]], axis=1)
    kpb = jnp.concatenate([kp_prev, kp], axis=2)               # (B,n,2C)

    kb = _repeat_kv(kb, hq, axis=3)
    vb = _repeat_kv(vb, hq, axis=3)
    scores = jnp.einsum("bnqhd,bnkhd->bnhqk", qc, kb,
                        preferred_element_type=jnp.float32)
    mask = (kpb[:, :, None, None, :] <= qp[:, :, None, :, None])
    mask &= (kpb[:, :, None, None, :] > qp[:, :, None, :, None] - window)
    mask &= (kpb[:, :, None, None, :] >= 0)
    scores = jnp.where(mask, scores, NEG_INF)
    p = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bnhqk,bnkhd->bnqhd", p.astype(vb.dtype), vb)
    return o.reshape(b, s, hq, d)


# ---------------------------------------------------------------------------
# Attention layer (projections + rope + core dispatch)
# ---------------------------------------------------------------------------
def attention_layer(p: dict, x: jax.Array, positions: jax.Array, *,
                    n_heads: int, n_kv_heads: int, head_dim: int,
                    rope_variant: str, rope_theta: float, mrope_sections,
                    window: int = 0, causal: bool = True,
                    chunk_threshold: int = 8192,
                    kv_override: Optional[Tuple[jax.Array, jax.Array]] = None,
                    kv_positions: Optional[jax.Array] = None,
                    policy: Optional[PrecisionPolicy] = None):
    """Full attention layer on a whole sequence (train / prefill).

    Returns (out, (k, v)) — the K/V tensors are returned so prefill can
    populate the cache.  ``kv_override`` feeds cross-attention.  All
    projections consume params through ``quant_matmul`` — float arrays
    and int8 ``QTensor`` weights take the same call convention.
    """
    b, s, _ = x.shape
    q = quant_matmul(x, p["wq"], policy=policy).reshape(
        b, s, n_heads, head_dim)
    if kv_override is None:
        k = quant_matmul(x, p["wk"], policy=policy).reshape(
            b, s, n_kv_heads, head_dim)
        v = quant_matmul(x, p["wv"], policy=policy).reshape(
            b, s, n_kv_heads, head_dim)
        k_pos = positions if positions.ndim == 2 else positions[..., 0]
        q, k = position_encode(q, k, positions, rope_variant, rope_theta,
                               mrope_sections)
    else:
        k, v = kv_override
        k_pos = kv_positions
        if rope_variant != "none":
            q = (apply_mrope(q, positions, rope_theta, mrope_sections)
                 if rope_variant == "mrope"
                 else apply_rope(q, positions, rope_theta))
    q = constrain(q, ("act_batch", "act_seq", "act_heads", None))
    k = constrain(k, ("act_batch", "act_kv_seq", "act_kv_heads", None))
    v = constrain(v, ("act_batch", "act_kv_seq", "act_kv_heads", None))

    q_pos1d = positions if positions.ndim == 2 else positions[..., 0]
    if window > 0 and causal and s % window == 0 and s > window:
        o = local_attention(q, k, v, q_pos1d, k_pos, window)
    elif window > 0 and causal:
        # irregular lengths (smoke shapes): windowed mask on full attention
        o = full_attention(q, k, v, q_pos1d, k_pos, window=window)
    elif k.shape[1] > chunk_threshold and causal:
        o = chunked_attention(q, k, v, q_pos1d, k_pos)
    else:
        o = full_attention(q, k, v, q_pos1d, k_pos, causal=causal)
    o = constrain(o, ("act_batch", "act_seq", "act_heads", None))
    out = quant_matmul(o.reshape(b, s, n_heads * head_dim), p["wo"],
                       policy=policy)
    return out, (k, v)


def attention_decode_layer(p: dict, x: jax.Array, position: jax.Array,
                           cache_k, cache_v,
                           cache_positions: jax.Array, write_idx: jax.Array, *,
                           n_heads: int, n_kv_heads: int, head_dim: int,
                           rope_variant: str, rope_theta: float,
                           mrope_sections, window: int = 0,
                           cross: bool = False,
                           policy: Optional[PrecisionPolicy] = None,
                           kv_len: Optional[jax.Array] = None,
                           active: Optional[jax.Array] = None,
                           block_table: Optional[jax.Array] = None):
    """One decode step.  x: (B, 1, d); position: (B,) absolute position;
    write_idx: (B,) slot to write KV into (ring index for sliding caches).

    ``cache_k``/``cache_v`` are float arrays or ``Int8KV`` pairs; int8
    caches get the new K/V quantized per (entry, head) on write and
    dequantized tile-by-tile inside the attention kernel — the decode
    path never materializes a float copy of the cache.  A fake_quant
    policy mirrors the numerics bit-exactly on a float cache (quantize→
    dequantize at write time), which is what makes int8 serving testable
    token-exact.

    ``kv_len`` (B,) optionally bounds each row's valid cache region by
    index (the serving tier's per-slot high-water mark); sliding-window
    ring caches derive their own bound from ``position`` (ring fill is a
    prefix of length min(position + 1, window)).

    ``active`` (B,) bool optionally predicates the cache writes: rows
    with ``active == False`` (idle serving slots, and slots mid-chunked-
    prefill) write their *existing* entry back, so a decode step can
    never scribble into a row another phase owns.  ``None`` writes
    unconditionally (single-sequence decode).

    ``block_table`` (B, n_blocks) switches to the **paged pool** layout
    (docs/paged_kv.md): ``cache_k``/``cache_v`` are (NB, BS, Hkv, D)
    pools (Int8KV scales (NB, BS, Hkv)), ``cache_positions`` is the
    (NB, BS) position pool, and this token's KV scatters into physical
    row ``(block_table[b, position // BS], position % BS)`` — inactive
    rows are routed out of bounds and dropped.  The scheduler owns the
    invariant that a written block has refcount 1 (prefix-shared blocks
    are never write targets), so the scatter targets are unique.  Only
    full (non-ring) self-attention caches are ever paged.

    Returns (out, new_cache_k, new_cache_v, new_cache_positions).
    """
    b = x.shape[0]
    q = quant_matmul(x, p["wq"], policy=policy).reshape(
        b, 1, n_heads, head_dim)
    if cross:
        # Cross attention: cache holds encoder KV; nothing is written.
        o = decode_attention(q, cache_k, cache_v,
                             jnp.full((b,), 2 ** 30, jnp.int32),
                             cache_positions)
        out = quant_matmul(o.reshape(b, 1, n_heads * head_dim), p["wo"],
                           policy=policy)
        return out, cache_k, cache_v, cache_positions
    k = quant_matmul(x, p["wk"], policy=policy).reshape(
        b, 1, n_kv_heads, head_dim)
    v = quant_matmul(x, p["wv"], policy=policy).reshape(
        b, 1, n_kv_heads, head_dim)
    if rope_variant == "mrope":
        pos3 = jnp.broadcast_to(position[:, None, None], (b, 1, 3))
        q = apply_mrope(q, pos3, rope_theta, mrope_sections)
        k = apply_mrope(k, pos3, rope_theta, mrope_sections)
    elif rope_variant == "rope":
        q = apply_rope(q, position[:, None], rope_theta)
        k = apply_rope(k, position[:, None], rope_theta)

    if block_table is not None:
        # Paged pool: this token's row lives at (table[b, pos // BS],
        # pos % BS).  Inactive rows scatter out of bounds → dropped.
        nb, bs = cache_positions.shape
        blk = jnp.take_along_axis(
            block_table, (write_idx // bs)[:, None], axis=1)[:, 0]
        off = write_idx % bs
        if active is not None:
            blk = jnp.where(active, blk, nb)

        def upd(cache, new):
            # new: (B, 1, ...) — one row per slot, unique (blk, off)
            # targets by the refcount-1 write invariant
            return cache.at[blk, off].set(new[:, 0].astype(cache.dtype),
                                          mode="drop")
    else:
        def upd(cache, new):
            if active is None:
                return jax.vmap(
                    lambda c, n, i: lax.dynamic_update_slice_in_dim(
                        c, n, i, axis=0)
                )(cache, new, write_idx)

            def one(c, n, i, a):
                old = lax.dynamic_slice_in_dim(c, i, n.shape[0], axis=0)
                return lax.dynamic_update_slice_in_dim(
                    c, jnp.where(a, n, old), i, axis=0)
            return jax.vmap(one)(cache, new, write_idx, active)

    if isinstance(cache_k, Int8KV):
        qk, qv = quant_kv(k), quant_kv(v)
        cache_k = Int8KV(upd(cache_k.q, qk.q), upd(cache_k.scale, qk.scale))
        cache_v = Int8KV(upd(cache_v.q, qv.q), upd(cache_v.scale, qv.scale))
    else:
        if (policy is not None and policy.kv_cache == "int8"
                and policy.compute == "fake_quant"):
            k = dequant_kv(quant_kv(k), k.dtype)
            v = dequant_kv(quant_kv(v), v.dtype)
        cache_k = upd(cache_k, k)
        cache_v = upd(cache_v, v)
    cache_positions = upd(cache_positions, position[:, None])
    cache_k = _constrain_decode_kv(cache_k)
    cache_v = _constrain_decode_kv(cache_v)
    s_kv = cache_positions.shape[1]
    if window > 0:
        # Ring cache: slots 0..min(position, w-1) are the only ones ever
        # written (slot = pos % w), so the fill is a prefix the kernel
        # can bound on; kv_len == 0 (an idle serving slot) still wins.
        bound = jnp.minimum(position.astype(jnp.int32) + 1, s_kv)
        if kv_len is not None:
            bound = jnp.minimum(bound, jnp.clip(kv_len, 0, s_kv))
    else:
        bound = kv_len
    o = decode_attention(q, cache_k, cache_v, position,
                         cache_positions, window=window, kv_len=bound,
                         block_table=block_table)
    out = quant_matmul(o.reshape(b, 1, n_heads * head_dim), p["wo"],
                       policy=policy)
    return out, cache_k, cache_v, cache_positions


def ring_scatter_idx(positions: jax.Array, window: int) -> jax.Array:
    """Ring write targets for a prefill chunk.  positions: (B, C)
    absolute chunk positions (−1 pad).  Returns (B, C) scatter indices
    into a ``window``-row ring: entry i lands at ``pos % window``; pad
    entries and entries older than the chunk's last ``window`` real
    tokens (which would collide with a newer in-chunk winner) are routed
    to index ``window`` — out of bounds, dropped by the scatter.
    """
    b, c = positions.shape
    valid = positions >= 0
    n_valid = valid.sum(axis=1, keepdims=True)               # (B, 1)
    i = jnp.broadcast_to(jnp.arange(c, dtype=positions.dtype)[None, :],
                         (b, c))
    winner = valid & (i >= n_valid - window)
    return jnp.where(winner, positions % window, window).astype(jnp.int32)


def _ring_scatter(cache: jax.Array, new: jax.Array, idx: jax.Array):
    """Per-row scatter of chunk entries into a ring cache.  cache:
    (B, w, ...), new: (B, C, ...), idx: (B, C) with out-of-bounds ==
    dropped (see ``ring_scatter_idx``)."""
    return jax.vmap(lambda c, n, i: c.at[i].set(n.astype(c.dtype)))(
        cache, new, idx)


def attention_chunk_layer(p: dict, x: jax.Array, positions: jax.Array,
                          cache_k, cache_v,
                          cache_positions: jax.Array, write_idx: jax.Array, *,
                          n_heads: int, n_kv_heads: int, head_dim: int,
                          rope_variant: str, rope_theta: float,
                          mrope_sections, window: int = 0,
                          cross: bool = False,
                          policy: Optional[PrecisionPolicy] = None,
                          kv_len: Optional[jax.Array] = None,
                          block_table: Optional[jax.Array] = None):
    """One chunk-prefill step: C tokens written unpadded into the slot's
    cache rows, attending over the slot's live KV prefix plus themselves.

    x: (B, C, d); positions: (B, C) absolute positions, −1 marking the
    pad tail of a ragged final chunk (pad entries are written with
    position −1 — invalid — and their outputs are discarded).

    * ``window == 0`` (full/global cache): the chunk's K/V is written at
      rows ``[write_idx, write_idx + C)`` *first*, then the chunk queries
      attend the cache bounded by ``kv_len`` (the post-write fill) — the
      rows ahead of the fill are dead by the slot contract, so the write
      is safe and in-chunk causality is pure position masking.
    * ``window > 0`` (ring cache): writing first would let early chunk
      entries overwrite ring history late queries still need, so the
      chunk attends ``[ring cache ∥ chunk]`` concatenated, then the last
      ``window`` real entries are scattered into their ``pos % window``
      slots (older ones can never be attended again).

    Int8KV caches quantize the chunk per (entry, head) before the write/
    concat — the fake-quant policy mirrors the round-trip in float, which
    is what keeps int8 chunked serving testable token-exact.

    ``block_table`` (B, n_blocks) switches the ``window == 0`` path to
    the paged-pool layout (docs/paged_kv.md): the chunk's C rows scatter
    into physical rows ``(table[b, (p + i) // BS], (p + i) % BS)`` —
    pad-tail rows included, stamped position −1, so a recycled block can
    never leak a stale position inside the post-write fill — and the
    attention resolves through the same table in the kernel index maps.

    Returns (out (B, C, d), new_cache_k, new_cache_v, new_cache_positions).
    """
    b, c, _ = x.shape
    q = quant_matmul(x, p["wq"], policy=policy).reshape(
        b, c, n_heads, head_dim)
    if cross:
        # Cross attention: cache holds encoder KV; nothing is written and
        # every (non-pad) query may attend every encoder entry.
        if rope_variant != "none":
            q = (apply_mrope(q, jnp.broadcast_to(positions[..., None],
                                                 (b, c, 3)),
                             rope_theta, mrope_sections)
                 if rope_variant == "mrope"
                 else apply_rope(q, positions, rope_theta))
        q_valid = jnp.where(positions >= 0, 2 ** 30, -1)
        o = chunk_attention(q, cache_k, cache_v, q_valid, cache_positions)
        out = quant_matmul(o.reshape(b, c, n_heads * head_dim), p["wo"],
                           policy=policy)
        return out, cache_k, cache_v, cache_positions
    k = quant_matmul(x, p["wk"], policy=policy).reshape(
        b, c, n_kv_heads, head_dim)
    v = quant_matmul(x, p["wv"], policy=policy).reshape(
        b, c, n_kv_heads, head_dim)
    if rope_variant == "mrope":
        pos3 = jnp.broadcast_to(positions[..., None], (b, c, 3))
        q = apply_mrope(q, pos3, rope_theta, mrope_sections)
        k = apply_mrope(k, pos3, rope_theta, mrope_sections)
    elif rope_variant == "rope":
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)

    if (policy is not None and policy.kv_cache == "int8"
            and policy.compute == "fake_quant"
            and not isinstance(cache_k, Int8KV)):
        k = dequant_kv(quant_kv(k), k.dtype)
        v = dequant_kv(quant_kv(v), v.dtype)

    if window > 0:
        # ring: attend [cache ∥ chunk], then scatter the winners in
        if isinstance(cache_k, Int8KV):
            qk, qv = quant_kv(k), quant_kv(v)
            k_all = Int8KV(jnp.concatenate([cache_k.q, qk.q], axis=1),
                           jnp.concatenate([cache_k.scale, qk.scale],
                                           axis=1))
            v_all = Int8KV(jnp.concatenate([cache_v.q, qv.q], axis=1),
                           jnp.concatenate([cache_v.scale, qv.scale],
                                           axis=1))
        else:
            k_all = jnp.concatenate([cache_k, k.astype(cache_k.dtype)],
                                    axis=1)
            v_all = jnp.concatenate([cache_v, v.astype(cache_v.dtype)],
                                    axis=1)
        pos_all = jnp.concatenate([cache_positions, positions], axis=1)
        o = chunk_attention(q, k_all, v_all, positions, pos_all,
                            window=window)
        idx = ring_scatter_idx(positions, window)
        if isinstance(cache_k, Int8KV):
            cache_k = Int8KV(_ring_scatter(cache_k.q, qk.q, idx),
                             _ring_scatter(cache_k.scale, qk.scale, idx))
            cache_v = Int8KV(_ring_scatter(cache_v.q, qv.q, idx),
                             _ring_scatter(cache_v.scale, qv.scale, idx))
        else:
            cache_k = _ring_scatter(cache_k, k, idx)
            cache_v = _ring_scatter(cache_v, v, idx)
        cache_positions = _ring_scatter(cache_positions, positions, idx)
    else:
        if block_table is not None:
            # Paged pool: row p + i of the chunk scatters into physical
            # (table[b, (p+i) // BS], (p+i) % BS).  Pad-tail rows write
            # too (their position stamp is −1), so no stale tenant
            # position survives inside the post-write fill p + C.
            bs = cache_positions.shape[1]
            tgt = write_idx[:, None] + jnp.arange(c, dtype=jnp.int32)[None]
            blk = jnp.take_along_axis(block_table, tgt // bs, axis=1)
            off = tgt % bs

            def upd(cache, new):
                # (B, C) index pairs — unique targets per refcount-1
                # write invariant (shared prefix blocks are skipped by
                # the scheduler, never written)
                return cache.at[blk, off].set(new.astype(cache.dtype))
        else:
            def upd(cache, new):
                return jax.vmap(
                    lambda cc, n, i: lax.dynamic_update_slice_in_dim(
                        cc, n.astype(cc.dtype), i, axis=0)
                )(cache, new, write_idx)

        if isinstance(cache_k, Int8KV):
            qk, qv = quant_kv(k), quant_kv(v)
            cache_k = Int8KV(upd(cache_k.q, qk.q),
                             upd(cache_k.scale, qk.scale))
            cache_v = Int8KV(upd(cache_v.q, qv.q),
                             upd(cache_v.scale, qv.scale))
        else:
            cache_k = upd(cache_k, k)
            cache_v = upd(cache_v, v)
        cache_positions = upd(cache_positions, positions)
        s_kv = cache_positions.shape[1]
        bound = None if kv_len is None else jnp.clip(kv_len, 0, s_kv)
        if block_table is not None:
            bound = kv_len
        o = chunk_attention(q, cache_k, cache_v, positions,
                            cache_positions, kv_len=bound,
                            block_table=block_table)
    cache_k = _constrain_decode_kv(cache_k)
    cache_v = _constrain_decode_kv(cache_v)
    out = quant_matmul(o.reshape(b, c, n_heads * head_dim), p["wo"],
                       policy=policy)
    return out, cache_k, cache_v, cache_positions


# ---------------------------------------------------------------------------
# MLP
# ---------------------------------------------------------------------------
def swiglu_mlp(p: dict, x: jax.Array,
               policy: Optional[PrecisionPolicy] = None) -> jax.Array:
    gate = quant_matmul(x, p["w_gate"], policy=policy)
    up = quant_matmul(x, p["w_up"], policy=policy)
    h = jax.nn.silu(gate) * up
    h = constrain(h, ("act_batch", "act_seq", "act_ff"))
    return quant_matmul(h, p["w_down"], policy=policy)
