"""Decoder-only backbone: dense / MoE / hybrid(Mamba2+shared-attn) / SSM / VLM.

One generic model consumes an ``ArchConfig``.  Depth is always lowered as
``lax.scan`` over stacked per-layer params (grouped scans for
heterogeneous patterns), so HLO size is O(1) in depth and remat policies
apply per scanned body.

Three entry points per arch:
* ``forward_train``   — full-sequence forward + LM loss (microbatch view).
* ``forward_prefill`` — full-sequence forward emitting a decode cache.
* ``forward_decode``  — one token against the cache (serve_step).
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from repro import flags
from repro.core.arch import ArchConfig
from repro.core.quantize import Int8KV, PrecisionPolicy, maybe_quant_kv
from repro.models import ssm as ssm_mod
from repro.models.layers import (attention_chunk_layer,
                                 attention_decode_layer, attention_layer,
                                 ring_scatter_idx, _ring_scatter,
                                 rms_norm, swiglu_mlp)
from repro.models.moe import moe_layer
from repro.models.params import layer_pattern
from repro.sharding.policy import constrain

def maybe_cast_params(params, cfg):
    """bf16_params flag: cast >=2D f32 masters to the activation dtype
    once at step entry, so FSDP all-gathers move bf16 (not f32 masters).
    1D scales / ssm dynamics / QTensor dequant scales stay f32."""
    if not flags.get("bf16_params"):
        return params
    dt = cfg.activation_dtype
    from repro.core.quantize import QTensor

    def cast(leaf):
        if isinstance(leaf, QTensor):
            return leaf
        if leaf.ndim >= 2 and leaf.dtype == jnp.float32:
            return leaf.astype(dt)
        return leaf
    casted = jax.tree.map(cast, params,
                          is_leaf=lambda x: isinstance(x, QTensor))
    # Barrier: without it XLA sinks the convert into the layer scan and
    # the FSDP all-gather still moves the f32 master (measured: zero
    # collective-byte change).  With it, the sharded bf16 copy
    # materializes once and every gather moves half the bytes.
    return jax.lax.optimization_barrier(casted)


REMAT_POLICIES = {
    "none": None,
    "full": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.checkpoint_dots,
    "dots_no_batch": jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
}


def _maybe_remat(fn, policy: Optional[str]):
    if policy is None or policy == "none":
        return fn
    return jax.checkpoint(fn, policy=REMAT_POLICIES[policy],
                          prevent_cse=False)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def embed_tokens(params, tokens: jax.Array, cfg: ArchConfig) -> jax.Array:
    table = params["embed"].astype(cfg.activation_dtype)
    x = jnp.take(table, tokens, axis=0)
    if cfg.family != "cnn":
        x = x * jnp.asarray(cfg.d_model ** 0.5, x.dtype) if cfg.name.startswith(
            "gemma") else x
    return constrain(x, ("act_batch", "act_res_seq", "act_dmodel"))


def unembed(params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    table = params.get("unembed", params["embed"])
    logits = jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype))
    return constrain(logits, ("act_batch", "act_seq", "act_vocab"))


def lm_loss(logits: jax.Array, labels: jax.Array, vocab_size: int
            ) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Cross-entropy with padded-vocab masking; labels == -1 are ignored."""
    v_pad = logits.shape[-1]
    logits = logits.astype(jnp.float32)
    if v_pad > vocab_size:
        col = lax.broadcasted_iota(jnp.int32, (v_pad,), 0)
        logits = logits + jnp.where(col < vocab_size, 0.0, -1e30)
    valid = labels >= 0
    safe_labels = jnp.maximum(labels, 0)
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, safe_labels[..., None], axis=-1)[..., 0]
    nll = (logz - picked) * valid
    n = jnp.maximum(valid.sum(), 1)
    loss = nll.sum() / n
    return loss, {"loss": loss, "tokens": n,
                  "ppl_log": loss}


# ---------------------------------------------------------------------------
# Block bodies
# ---------------------------------------------------------------------------
def _attn_kwargs(cfg: ArchConfig, window: int = 0):
    return dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
                head_dim=cfg.resolved_head_dim, rope_variant=cfg.rope_variant,
                rope_theta=cfg.rope_theta, mrope_sections=cfg.mrope_sections,
                window=window)


def dense_block(cfg: ArchConfig, p, x, positions, *, window=0,
                causal=True, collect_kv=False, policy=None):
    h = rms_norm(p["attn_norm"], x, cfg.norm_eps)
    attn_out, kv = attention_layer(p["attn"], h, positions, causal=causal,
                                   policy=policy, **_attn_kwargs(cfg, window))
    x = x + attn_out
    h = rms_norm(p["mlp_norm"], x, cfg.norm_eps)
    x = x + swiglu_mlp(p["mlp"], h, policy)
    x = constrain(x, ("act_batch", "act_res_seq", "act_dmodel"))
    return (x, kv) if collect_kv else (x, None)


def moe_block(cfg: ArchConfig, p, x, positions, *, collect_kv=False,
              policy=None):
    h = rms_norm(p["attn_norm"], x, cfg.norm_eps)
    attn_out, kv = attention_layer(p["attn"], h, positions, policy=policy,
                                   **_attn_kwargs(cfg))
    x = x + attn_out
    h = rms_norm(p["mlp_norm"], x, cfg.norm_eps)
    x = x + moe_layer(p["moe"], h, cfg)
    x = constrain(x, ("act_batch", "act_res_seq", "act_dmodel"))
    return (x, kv) if collect_kv else (x, None)


def mamba_block(cfg: ArchConfig, p, x, state=None):
    h = rms_norm(p["norm"], x, cfg.norm_eps)
    fn = (ssm_mod.mamba2_layer if cfg.ssm_variant == "mamba2"
          else ssm_mod.mamba1_layer)
    y, new_state = fn(p["mamba"], h, cfg, state)
    x = x + y
    x = constrain(x, ("act_batch", "act_res_seq", "act_dmodel"))
    return x, new_state


def dense_block_decode(cfg: ArchConfig, p, x, position, cache_k, cache_v,
                       cache_pos, write_idx, *, window=0, policy=None,
                       kv_len=None, active=None, block_table=None):
    h = rms_norm(p["attn_norm"], x, cfg.norm_eps)
    attn_out, ck, cv, cp = attention_decode_layer(
        p["attn"], h, position, cache_k, cache_v, cache_pos, write_idx,
        policy=policy, kv_len=kv_len, active=active,
        block_table=block_table, **_attn_kwargs(cfg, window))
    x = x + attn_out
    h = rms_norm(p["mlp_norm"], x, cfg.norm_eps)
    x = x + swiglu_mlp(p["mlp"], h, policy)
    return x, ck, cv, cp


def moe_block_decode(cfg: ArchConfig, p, x, position, cache_k, cache_v,
                     cache_pos, write_idx, policy=None, kv_len=None,
                     active=None, block_table=None):
    h = rms_norm(p["attn_norm"], x, cfg.norm_eps)
    attn_out, ck, cv, cp = attention_decode_layer(
        p["attn"], h, position, cache_k, cache_v, cache_pos, write_idx,
        policy=policy, kv_len=kv_len, active=active,
        block_table=block_table, **_attn_kwargs(cfg))
    x = x + attn_out
    h = rms_norm(p["mlp_norm"], x, cfg.norm_eps)
    x = x + moe_layer(p["moe"], h, cfg)
    return x, ck, cv, cp


def mamba_block_decode(cfg: ArchConfig, p, x, state, active=None):
    h = rms_norm(p["norm"], x, cfg.norm_eps)
    fn = (ssm_mod.mamba2_decode if cfg.ssm_variant == "mamba2"
          else ssm_mod.mamba1_decode)
    y, new_state = fn(p["mamba"], h, cfg, state)
    if active is not None:
        # idle serving slots keep their state: a decode step must never
        # advance the recurrence of a row another phase (chunked prefill)
        # owns.
        new_state = jax.tree.map(
            lambda n, o: jnp.where(
                active.reshape((-1,) + (1,) * (n.ndim - 1)), n, o),
            new_state, state)
    return x + y, new_state


# ---------------------------------------------------------------------------
# Chunk-prefill block bodies (C tokens against the live slot cache)
# ---------------------------------------------------------------------------
def dense_block_chunk(cfg: ArchConfig, p, x, positions, cache_k, cache_v,
                      cache_pos, write_idx, *, window=0, policy=None,
                      kv_len=None, block_table=None):
    h = rms_norm(p["attn_norm"], x, cfg.norm_eps)
    attn_out, ck, cv, cp = attention_chunk_layer(
        p["attn"], h, positions, cache_k, cache_v, cache_pos, write_idx,
        policy=policy, kv_len=kv_len, block_table=block_table,
        **_attn_kwargs(cfg, window))
    x = x + attn_out
    h = rms_norm(p["mlp_norm"], x, cfg.norm_eps)
    x = x + swiglu_mlp(p["mlp"], h, policy)
    return x, ck, cv, cp


def moe_block_chunk(cfg: ArchConfig, p, x, positions, cache_k, cache_v,
                    cache_pos, write_idx, policy=None, kv_len=None,
                    block_table=None):
    h = rms_norm(p["attn_norm"], x, cfg.norm_eps)
    attn_out, ck, cv, cp = attention_chunk_layer(
        p["attn"], h, positions, cache_k, cache_v, cache_pos, write_idx,
        policy=policy, kv_len=kv_len, block_table=block_table,
        **_attn_kwargs(cfg))
    x = x + attn_out
    h = rms_norm(p["mlp_norm"], x, cfg.norm_eps)
    x = x + moe_layer(p["moe"], h, cfg)
    return x, ck, cv, cp


def mamba_block_chunk(cfg: ArchConfig, p, x, state, mask, fill):
    h = rms_norm(p["norm"], x, cfg.norm_eps)
    fn = (ssm_mod.mamba2_layer if cfg.ssm_variant == "mamba2"
          else ssm_mod.mamba1_layer)
    y, new_state = fn(p["mamba"], h, cfg, state, mask=mask, fill=fill)
    x = x + y
    return x, new_state


# ---------------------------------------------------------------------------
# Trunk (pattern-dispatched scans)
# ---------------------------------------------------------------------------
def trunk_forward(cfg: ArchConfig, params, x, positions, *,
                  remat: str = "none", collect_cache: bool = False,
                  policy: Optional[PrecisionPolicy] = None):
    """Run all blocks.  Returns (x, cache_entries | None)."""
    pat = layer_pattern(cfg)
    caches: Dict[str, jax.Array] = {}

    if pat["kind"] in ("uniform_dense", "uniform_moe"):
        is_moe = pat["kind"] == "uniform_moe"

        def body(h, p):
            fn = moe_block if is_moe else dense_block
            h, kv = fn(cfg, p, h, positions, collect_kv=collect_cache,
                       policy=policy)
            return h, kv
        body = _maybe_remat(body, remat)
        x, kvs = lax.scan(body, x, params["blocks"])
        if collect_cache and kvs is not None:
            caches["k"], caches["v"] = kvs

    elif pat["kind"] == "uniform_ssm":
        def body(h, p):
            h, st = mamba_block(cfg, p, h)
            return h, st if collect_cache else None
        body = _maybe_remat(body, remat)
        x, states = lax.scan(body, x, params["blocks"])
        if collect_cache:
            caches["ssm"] = states

    elif pat["kind"] == "local_global":
        w = cfg.sliding_window

        def local_body(h, p):
            h, kv = dense_block(cfg, p, h, positions, window=w,
                                collect_kv=collect_cache, policy=policy)
            return h, kv

        def group_body(h, p):
            h, local_kv = lax.scan(_maybe_remat(local_body, remat),
                                   h, p["local"])
            h, global_kv = _maybe_remat(
                lambda hh, pp: dense_block(cfg, pp, hh, positions,
                                           collect_kv=collect_cache,
                                           policy=policy),
                remat)(h, p["global"])
            return h, (local_kv, global_kv)

        x, (local_kvs, global_kvs) = lax.scan(
            group_body, x,
            {"local": params["groups"]["local"],
             "global": params["groups"]["global"]})
        if "tail_local" in params:
            x, tail_kvs = lax.scan(_maybe_remat(local_body, remat), x,
                                   params["tail_local"])
        else:
            tail_kvs = None
        if collect_cache:
            caches["local_k"], caches["local_v"] = local_kvs
            caches["global_k"], caches["global_v"] = global_kvs
            if tail_kvs is not None:
                caches["tail_k"], caches["tail_v"] = tail_kvs

    elif pat["kind"] == "hybrid":
        shared = params["shared_attn"]

        def mamba_body(h, p):
            h, st = mamba_block(cfg, p, h)
            return h, st if collect_cache else None

        def group_body(h, p):
            h, states = lax.scan(_maybe_remat(mamba_body, remat), h, p)
            h, kv = _maybe_remat(
                lambda hh, pp: dense_block(cfg, pp, hh, positions,
                                           collect_kv=collect_cache,
                                           policy=policy),
                remat)(h, shared)
            return h, (states, kv)

        x, (states, kvs) = lax.scan(group_body, x, params["groups"])
        if collect_cache:
            caches["ssm"] = states
            caches["attn_k"], caches["attn_v"] = kvs
    else:
        raise ValueError(pat)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x, (caches if collect_cache else None)


def trunk_decode(cfg: ArchConfig, params, x, position, cache, *,
                 write_full, write_local,
                 policy: Optional[PrecisionPolicy] = None,
                 kv_len: Optional[jax.Array] = None,
                 active: Optional[jax.Array] = None,
                 block_table: Optional[jax.Array] = None):
    """One-token pass through all blocks, updating the cache pytree.

    ``kv_len`` (B,) is the per-row high-water mark of the full-attention
    caches (serving passes each slot's fill so the decode kernel skips
    the unused capacity tail); ring caches bound themselves from
    ``position``.  ``active`` (B,) bool predicates every cache/state
    write — inactive rows (idle slots, slots mid-chunked-prefill) come
    through the step bit-identical.

    ``block_table`` (B, n_blocks) marks the cache as **paged**: the
    full-attention KV leaves are block pools addressed through the table
    (positions in ``cache["pool_pos"]``), while sliding-window ring
    caches and SSM state stay slot-addressed — they are O(window) /
    O(state) per slot already, there is no capacity tail to reclaim
    (docs/paged_kv.md).
    """
    pat = layer_pattern(cfg)
    new_cache = dict(cache)
    # paged caches keep full-attention positions in the (NB, BS) pool
    full_pos = cache["pool_pos" if block_table is not None else "full_pos"] \
        if pat["kind"] != "uniform_ssm" else None

    if pat["kind"] in ("uniform_dense", "uniform_moe"):
        is_moe = pat["kind"] == "uniform_moe"

        def body(h, pc):
            p, ck, cv = pc
            fn = moe_block_decode if is_moe else dense_block_decode
            h, ck, cv, cp = fn(cfg, p, h, position, ck, cv,
                               full_pos, write_full, policy=policy,
                               kv_len=kv_len, active=active,
                               block_table=block_table)
            return h, (ck, cv)
        x, (ks, vs) = lax.scan(body, x, (params["blocks"],
                                         cache["k"], cache["v"]))
        new_cache["k"], new_cache["v"] = ks, vs

    elif pat["kind"] == "uniform_ssm":
        def body(h, pc):
            p, st = pc
            h, st = mamba_block_decode(cfg, p, h, ssm_mod.SSMState(*st),
                                       active=active)
            return h, tuple(st)
        x, states = lax.scan(body, x, (params["blocks"],
                                       tuple(cache["ssm"])))
        new_cache["ssm"] = ssm_mod.SSMState(*states)

    elif pat["kind"] == "local_global":
        w = cfg.sliding_window

        def local_body(h, pc):
            p, ck, cv = pc
            h, ck, cv, cp = dense_block_decode(
                cfg, p, h, position, ck, cv, cache["local_pos"],
                write_local, window=w, policy=policy, kv_len=kv_len,
                active=active)
            return h, (ck, cv)

        def group_body(h, pc):
            p, lk, lv, gk, gv = pc
            h, (lks, lvs) = lax.scan(local_body, h, (p["local"], lk, lv))
            h, gk, gv, _ = dense_block_decode(
                cfg, p["global"], h, position, gk, gv,
                full_pos, write_full, policy=policy, kv_len=kv_len,
                active=active, block_table=block_table)
            return h, (lks, lvs, gk, gv)

        x, (lks, lvs, gks, gvs) = lax.scan(
            group_body, x,
            ({"local": params["groups"]["local"],
              "global": params["groups"]["global"]},
             cache["local_k"], cache["local_v"],
             cache["global_k"], cache["global_v"]))
        new_cache.update(local_k=lks, local_v=lvs,
                         global_k=gks, global_v=gvs)
        if "tail_k" in cache:
            x, (tks, tvs) = lax.scan(
                local_body, x,
                (params["tail_local"], cache["tail_k"], cache["tail_v"]))
            new_cache.update(tail_k=tks, tail_v=tvs)

    elif pat["kind"] == "hybrid":
        shared = params["shared_attn"]

        def mamba_body(h, pc):
            p, st = pc
            h, st = mamba_block_decode(cfg, p, h, ssm_mod.SSMState(*st),
                                       active=active)
            return h, tuple(st)

        def group_body(h, pc):
            p, st, ck, cv = pc
            h, states = lax.scan(mamba_body, h, (p, tuple(st)))
            h, ck, cv, _ = dense_block_decode(
                cfg, shared, h, position, ck, cv,
                full_pos, write_full, policy=policy, kv_len=kv_len,
                active=active, block_table=block_table)
            return h, (states, ck, cv)

        x, (states, ks, vs) = lax.scan(
            group_body, x,
            (params["groups"], tuple(cache["ssm"]),
             cache["attn_k"], cache["attn_v"]))
        new_cache["ssm"] = ssm_mod.SSMState(*states)
        new_cache["attn_k"], new_cache["attn_v"] = ks, vs
    else:
        raise ValueError(pat)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x, new_cache


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------
def default_positions(cfg: ArchConfig, batch: int, seq: int) -> jax.Array:
    pos = jnp.broadcast_to(jnp.arange(seq, dtype=jnp.int32), (batch, seq))
    if cfg.rope_variant == "mrope":
        return jnp.broadcast_to(pos[..., None], (batch, seq, 3))
    return pos


# ---------------------------------------------------------------------------
# Entry points
# ---------------------------------------------------------------------------
def forward_train(cfg: ArchConfig, params, inputs: Dict[str, jax.Array], *,
                  remat: str = "full",
                  policy: Optional[PrecisionPolicy] = None):
    """inputs: tokens (B,S) int32 OR embeddings (B,S,d); labels (B,S)."""
    params = maybe_cast_params(params, cfg)
    if "embeddings" in inputs:
        x = inputs["embeddings"].astype(cfg.activation_dtype)
        x = constrain(x, ("act_batch", "act_res_seq", "act_dmodel"))
        b, s = x.shape[:2]
    else:
        tokens = inputs["tokens"]
        b, s = tokens.shape
        x = embed_tokens(params, tokens, cfg)
    positions = inputs.get("positions")
    if positions is None:
        positions = default_positions(cfg, b, s)
    x, _ = trunk_forward(cfg, params, x, positions, remat=remat,
                         policy=policy)
    logits = unembed(params, x, cfg)
    return lm_loss(logits, inputs["labels"], cfg.vocab_size)


def forward_prefill(cfg: ArchConfig, params, inputs: Dict[str, jax.Array],
                    policy: Optional[PrecisionPolicy] = None):
    """Returns (last_token_logits, cache).  ``policy`` selects the KV
    cache representation (float / Int8KV / fake-quant float) and the
    matmul compute mode for QTensor params."""
    params = maybe_cast_params(params, cfg)
    if "embeddings" in inputs:
        x = inputs["embeddings"].astype(cfg.activation_dtype)
        b, s = x.shape[:2]
    else:
        tokens = inputs["tokens"]
        b, s = tokens.shape
        x = embed_tokens(params, tokens, cfg)
    positions = inputs.get("positions")
    if positions is None:
        positions = default_positions(cfg, b, s)
    x, caches = trunk_forward(cfg, params, x, positions, collect_cache=True,
                              policy=policy)
    logits = unembed(params, x[:, -1:, :], cfg)[:, 0]
    cache = _cache_from_prefill(cfg, caches, positions, b, s, policy=policy)
    return logits, cache


def forward_decode(cfg: ArchConfig, params, cache, token: jax.Array,
                   position: jax.Array, write_idx: Optional[jax.Array] = None,
                   policy: Optional[PrecisionPolicy] = None,
                   kv_len: Optional[jax.Array] = None,
                   block_table: Optional[jax.Array] = None):
    """token: (B,) int32; position: (B,) absolute index of this token.

    ``write_idx`` (B,) is the cache slot row index to write KV into; it
    defaults to ``position``, which is also what the serving engine uses
    — pad-free chunked admission keeps every cache row contiguous in
    positions, so index == position always.  (The override remains for
    callers with exotic layouts.)  Attention validity is always decided
    by stored positions, never by slot index.

    ``kv_len`` (B,) optionally bounds each row's live cache region by
    index: the caller promises every entry at index >= kv_len is invalid
    (position −1), letting the decode kernel skip the capacity tail.
    ``kv_len == 0`` marks an idle serving slot: its row is skipped by the
    kernel AND every cache/state write for it is suppressed — the step
    cannot scribble into a row the scheduler has parked or is chunk-
    prefilling.  ``None`` scans (and writes) the whole cache — masking
    alone still guarantees correctness.

    ``block_table`` (B, n_blocks) marks ``cache`` as a **paged** decode
    cache (full-attention KV block pools + ``pool_pos``; ring/SSM leaves
    slot-addressed as ever — see docs/paged_kv.md); ``kv_len`` is then
    required and the write lands in the physical block the table names.
    """
    params = maybe_cast_params(params, cfg)
    x = embed_tokens(params, token[:, None], cfg)
    w = cfg.sliding_window
    write_full = position if write_idx is None else write_idx
    write_local = position % w if w else write_full
    active = None if kv_len is None else kv_len > 0
    x, new_cache = trunk_decode(cfg, params, x, position, cache,
                                write_full=write_full,
                                write_local=write_local, policy=policy,
                                kv_len=kv_len, active=active,
                                block_table=block_table)
    logits = unembed(params, x, cfg)[:, 0]
    # position bookkeeping lives outside trunk_decode (shared across layers)
    if "pool_pos" in new_cache:
        new_cache["pool_pos"] = _write_pool_pos(
            new_cache["pool_pos"], position[:, None], write_full,
            block_table, active)
    elif "full_pos" in new_cache:
        new_cache["full_pos"] = _write_pos(new_cache["full_pos"], position,
                                           write_full, active)
    if "local_pos" in new_cache:
        new_cache["local_pos"] = _write_pos(new_cache["local_pos"], position,
                                            write_local, active)
    return logits, new_cache


def _write_pos(pos_arr, position, idx, active=None):
    if active is None:
        return jax.vmap(
            lambda cp, pv, i: lax.dynamic_update_slice_in_dim(cp, pv[None],
                                                              i, 0)
        )(pos_arr, position, idx)

    def one(cp, pv, i, a):
        old = lax.dynamic_slice_in_dim(cp, i, 1, 0)
        return lax.dynamic_update_slice_in_dim(
            cp, jnp.where(a, pv[None], old), i, 0)
    return jax.vmap(one)(pos_arr, position, idx, active)


def _write_pos_chunk(pos_arr, positions, idx):
    """Stamp a whole chunk's (B, C) positions at per-row offset ``idx``
    — the multi-entry sibling of ``_write_pos`` (pad tail entries carry
    −1 and are written invalid)."""
    return jax.vmap(
        lambda cp, pv, i: lax.dynamic_update_slice_in_dim(cp, pv, i, 0)
    )(pos_arr, positions, idx)


def _write_pool_pos(pool_pos, positions, write_idx, block_table,
                    active=None):
    """Paged sibling of ``_write_pos``/``_write_pos_chunk``: stamp (B, C)
    positions into the (NB, BS) position pool at logical rows
    ``[write_idx, write_idx + C)`` resolved through ``block_table``;
    rows with ``active == False`` are routed out of bounds and dropped.
    Pad entries (position −1) are stamped too — that is what keeps a
    recycled physical block free of stale tenant positions inside the
    post-write fill."""
    nb, bs = pool_pos.shape
    c = positions.shape[1]
    tgt = write_idx[:, None] + jnp.arange(c, dtype=jnp.int32)[None]
    blk = jnp.take_along_axis(block_table, tgt // bs, axis=1)
    if active is not None:
        blk = jnp.where(active[:, None], blk, nb)
    return pool_pos.at[blk, tgt % bs].set(positions, mode="drop")


# ---------------------------------------------------------------------------
# Chunked pad-free prefill (serving admission path)
# ---------------------------------------------------------------------------
def trunk_prefill_chunk(cfg: ArchConfig, params, x, positions, cache, *,
                        write_full,
                        policy: Optional[PrecisionPolicy] = None,
                        kv_len: Optional[jax.Array] = None,
                        block_table: Optional[jax.Array] = None):
    """C-token pass through all blocks against the live slot cache.

    The chunk sibling of ``trunk_decode``: attention layers write the
    chunk's KV unpadded into rows ``[write_full, write_full + C)`` (ring
    layers scatter at ``pos % window``) and attend the slot's live
    prefix plus the chunk; SSM layers advance the carried recurrent
    state over exactly the chunk's real tokens (pad steps of a ragged
    final chunk are exact no-ops).

    ``block_table`` (B, n_blocks) marks the cache as paged, exactly as
    in ``trunk_decode`` (full-attention leaves are block pools, ring /
    SSM leaves stay slot-addressed).
    """
    pat = layer_pattern(cfg)
    new_cache = dict(cache)
    mask = positions >= 0
    fill = mask.sum(axis=1).astype(jnp.int32)
    full_pos = cache["pool_pos" if block_table is not None else "full_pos"] \
        if pat["kind"] != "uniform_ssm" else None

    if pat["kind"] in ("uniform_dense", "uniform_moe"):
        is_moe = pat["kind"] == "uniform_moe"

        def body(h, pc):
            p, ck, cv = pc
            fn = moe_block_chunk if is_moe else dense_block_chunk
            h, ck, cv, cp = fn(cfg, p, h, positions, ck, cv,
                               full_pos, write_full, policy=policy,
                               kv_len=kv_len, block_table=block_table)
            return h, (ck, cv)
        x, (ks, vs) = lax.scan(body, x, (params["blocks"],
                                         cache["k"], cache["v"]))
        new_cache["k"], new_cache["v"] = ks, vs

    elif pat["kind"] == "uniform_ssm":
        def body(h, pc):
            p, st = pc
            h, st = mamba_block_chunk(cfg, p, h, ssm_mod.SSMState(*st),
                                      mask, fill)
            return h, tuple(st)
        x, states = lax.scan(body, x, (params["blocks"],
                                       tuple(cache["ssm"])))
        new_cache["ssm"] = ssm_mod.SSMState(*states)

    elif pat["kind"] == "local_global":
        w = cfg.sliding_window

        def local_body(h, pc):
            p, ck, cv = pc
            h, ck, cv, cp = dense_block_chunk(
                cfg, p, h, positions, ck, cv, cache["local_pos"],
                write_full, window=w, policy=policy, kv_len=kv_len)
            return h, (ck, cv)

        def group_body(h, pc):
            p, lk, lv, gk, gv = pc
            h, (lks, lvs) = lax.scan(local_body, h, (p["local"], lk, lv))
            h, gk, gv, _ = dense_block_chunk(
                cfg, p["global"], h, positions, gk, gv,
                full_pos, write_full, policy=policy, kv_len=kv_len,
                block_table=block_table)
            return h, (lks, lvs, gk, gv)

        x, (lks, lvs, gks, gvs) = lax.scan(
            group_body, x,
            ({"local": params["groups"]["local"],
              "global": params["groups"]["global"]},
             cache["local_k"], cache["local_v"],
             cache["global_k"], cache["global_v"]))
        new_cache.update(local_k=lks, local_v=lvs,
                         global_k=gks, global_v=gvs)
        if "tail_k" in cache:
            x, (tks, tvs) = lax.scan(
                local_body, x,
                (params["tail_local"], cache["tail_k"], cache["tail_v"]))
            new_cache.update(tail_k=tks, tail_v=tvs)

    elif pat["kind"] == "hybrid":
        shared = params["shared_attn"]

        def mamba_body(h, pc):
            p, st = pc
            h, st = mamba_block_chunk(cfg, p, h, ssm_mod.SSMState(*st),
                                      mask, fill)
            return h, tuple(st)

        def group_body(h, pc):
            p, st, ck, cv = pc
            h, states = lax.scan(mamba_body, h, (p, tuple(st)))
            h, ck, cv, _ = dense_block_chunk(
                cfg, shared, h, positions, ck, cv,
                full_pos, write_full, policy=policy, kv_len=kv_len,
                block_table=block_table)
            return h, (states, ck, cv)

        x, (states, ks, vs) = lax.scan(
            group_body, x,
            (params["groups"], tuple(cache["ssm"]),
             cache["attn_k"], cache["attn_v"]))
        new_cache["ssm"] = ssm_mod.SSMState(*states)
        new_cache["attn_k"], new_cache["attn_v"] = ks, vs
    else:
        raise ValueError(pat)

    x = rms_norm(params["final_norm"], x, cfg.norm_eps)
    return x, new_cache


def forward_prefill_chunk(cfg: ArchConfig, params, cache,
                          tokens: jax.Array, positions: jax.Array,
                          policy: Optional[PrecisionPolicy] = None,
                          kv_len: Optional[jax.Array] = None,
                          block_table: Optional[jax.Array] = None):
    """One fixed-size prefill chunk against a live slot cache.

    tokens: (B, C) int32; positions: (B, C) absolute positions — the
    chunk covers ``[p, p + C)`` of its prompt with ``p = positions[:, 0]``
    (the first entry is always a real token); a ragged final chunk pads
    the tail with position −1 (pad rows are written invalid and their
    logits are garbage the caller must ignore).

    ``kv_len`` (B,) is the post-write fill ``p + C`` bounding the
    attention sweep (``None`` scans the whole capacity; stored positions
    still decide validity).  Returns (logits (B, C, vocab), new_cache):
    the caller reads the next token from the last *real* row's logits.

    Calling this ceil(S / C) times over a prompt of length S reproduces
    ``forward_prefill``'s cache and final-token logits without a single
    pad row entering the KV cache or the SSM recurrence — the admission
    path of the chunked continuous-batching engine.
    """
    params = maybe_cast_params(params, cfg)
    x = embed_tokens(params, tokens, cfg)
    w = cfg.sliding_window
    write_full = positions[:, 0]
    x, new_cache = trunk_prefill_chunk(cfg, params, x, positions, cache,
                                       write_full=write_full, policy=policy,
                                       kv_len=kv_len,
                                       block_table=block_table)
    logits = unembed(params, x, cfg)
    # position bookkeeping outside the trunk (shared across layers)
    if "pool_pos" in new_cache:
        new_cache["pool_pos"] = _write_pool_pos(
            new_cache["pool_pos"], positions, write_full, block_table)
    elif "full_pos" in new_cache:
        new_cache["full_pos"] = _write_pos_chunk(new_cache["full_pos"],
                                                 positions, write_full)
    if "local_pos" in new_cache:
        idx = ring_scatter_idx(positions, w)
        new_cache["local_pos"] = _ring_scatter(new_cache["local_pos"],
                                               positions, idx)
    return logits, new_cache


# ---------------------------------------------------------------------------
# Cache construction
# ---------------------------------------------------------------------------
def _ring_select(pos1d: jax.Array, w: int):
    """Per-row ring placement for sliding-window caches.

    pos1d: (B, S) absolute positions, −1 marking invalid (pad) entries.
    The ring keeps, per row, the w most-recent *real* entries at slot
    ``pos % w``.  Returns (src, has, local_pos): source index into S
    per ring slot, slot validity, and the stored position per slot
    (−1 when empty) — per-row, so padded batches with different pad
    widths per sequence stay correct.
    """
    max_pos = jnp.max(pos1d, axis=1, keepdims=True)            # (B, 1)
    keep = (pos1d >= 0) & (pos1d > max_pos - w)                # (B, S)
    slot_of = jnp.where(keep, pos1d % w, w)                    # w = "none"
    slot_ids = jnp.arange(w, dtype=pos1d.dtype)[None, :, None]
    match = slot_of[:, None, :] == slot_ids                    # (B, w, S)
    src = jnp.argmax(match, axis=-1)                           # (B, w)
    has = jnp.any(match, axis=-1)                              # (B, w)
    local_pos = jnp.where(has, jnp.take_along_axis(pos1d, src, axis=1),
                          -1).astype(jnp.int32)
    return src, has, local_pos


def _ring_from_prefill(k: jax.Array, src: jax.Array, has: jax.Array):
    """Gather (.., B, S, kv, hd) into ring layout (.., B, w, kv, hd)
    according to ``_ring_select``'s placement.  Leading stacked dims are
    preserved; empty slots are zeroed (masked by local_pos == −1)."""
    b, w = src.shape
    shape_idx = (1,) * (k.ndim - 4) + (b, w, 1, 1)
    idx = jnp.broadcast_to(src.reshape(shape_idx),
                           k.shape[:-3] + (w,) + k.shape[-2:])
    out = jnp.take_along_axis(k, idx, axis=-3)
    return jnp.where(jnp.broadcast_to(has.reshape(shape_idx), out.shape),
                     out, jnp.zeros((), out.dtype))


def _constrain_kv_cache(arr: jax.Array) -> jax.Array:
    """Stacked KV cache (..., B, S, kv, hd): store seq-sharded ("model"
    under prefill rules) — a replicated 32k cache costs model-axis ×
    the HBM (measured 21.5 GiB/device on qwen2-72b prefill)."""
    nd = arr.ndim
    axes = (None,) * (nd - 4) + ("act_batch", "act_cache_seq",
                                 "act_kv_heads", None)
    return constrain(arr, axes)


def _cache_from_prefill(cfg: ArchConfig, caches, positions, b, s,
                        policy: Optional[PrecisionPolicy] = None):
    caches = {k: (_constrain_kv_cache(v) if k.split("_")[-1] in ("k", "v")
                  else v)
              for k, v in caches.items()}
    cache: Dict[str, jax.Array] = {}
    pos1d = positions if positions.ndim == 2 else positions[..., 0]
    pat = layer_pattern(cfg)
    w = cfg.sliding_window

    if pat["kind"] in ("uniform_dense", "uniform_moe"):
        cache["k"], cache["v"] = caches["k"], caches["v"]
        cache["full_pos"] = pos1d
    elif pat["kind"] == "uniform_ssm":
        cache["ssm"] = caches["ssm"]
    elif pat["kind"] == "local_global":
        src, has, local_pos = _ring_select(pos1d, w)
        cache["local_k"] = _ring_from_prefill(caches["local_k"], src, has)
        cache["local_v"] = _ring_from_prefill(caches["local_v"], src, has)
        cache["global_k"], cache["global_v"] = (caches["global_k"],
                                                caches["global_v"])
        if "tail_k" in caches:
            cache["tail_k"] = _ring_from_prefill(caches["tail_k"], src, has)
            cache["tail_v"] = _ring_from_prefill(caches["tail_v"], src, has)
        cache["full_pos"] = pos1d
        cache["local_pos"] = local_pos
    elif pat["kind"] == "hybrid":
        cache["ssm"] = caches["ssm"]
        cache["attn_k"], cache["attn_v"] = caches["attn_k"], caches["attn_v"]
        cache["full_pos"] = pos1d
    if policy is not None and policy.kv_cache == "int8":
        # Quantize AFTER ring reconstruction (gather commutes with
        # per-entry quantization) so one code path covers every layout.
        cache = {key: (maybe_quant_kv(policy, arr)
                       if key.split("_")[-1] in ("k", "v") else arr)
                 for key, arr in cache.items()}
    return cache


def _grow_axis(arr: jax.Array, axis: int, extra: int) -> jax.Array:
    pad = [(0, 0)] * arr.ndim
    pad[axis] = (0, extra)
    return jnp.pad(arr, pad)


def grow_cache(cfg: ArchConfig, cache, extra: int):
    """Extend full-attention cache seq dims by ``extra`` slots (padded)."""
    def grow(name, arr):
        if isinstance(arr, Int8KV):
            return Int8KV(_grow_axis(arr.q, -3, extra),
                          _grow_axis(arr.scale, -2, extra))
        return _grow_axis(arr, -3, extra)

    out = dict(cache)
    for key in ("k", "v", "global_k", "global_v", "attn_k", "attn_v"):
        if key in out:
            out[key] = grow(key, out[key])
    if "full_pos" in out:
        out["full_pos"] = jnp.pad(out["full_pos"], ((0, 0), (0, extra)),
                                  constant_values=-1)
    return out
