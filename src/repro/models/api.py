"""Unified model API: family dispatch + input specs for every shape cell.

``input_specs(cfg, shape)`` returns ShapeDtypeStruct stand-ins for every
model input (weak-type-correct, shardable, no device allocation) — the
dry-run consumes these directly.  Modality frontends are STUBS: audio/vlm
archs receive precomputed frame/patch embeddings here.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.arch import ArchConfig, ShapeConfig
from repro.models import encdec, transformer
from repro.models.params import abstract_params


class ModelFns(NamedTuple):
    forward_train: Callable
    forward_prefill: Callable
    forward_decode: Callable
    forward_prefill_chunk: Callable


def model_fns(cfg: ArchConfig) -> ModelFns:
    if cfg.is_encdec:
        return ModelFns(encdec.forward_train, encdec.forward_prefill,
                        encdec.forward_decode, encdec.forward_prefill_chunk)
    return ModelFns(transformer.forward_train, transformer.forward_prefill,
                    transformer.forward_decode,
                    transformer.forward_prefill_chunk)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStructs) per shape kind
# ---------------------------------------------------------------------------
def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), jnp.dtype(dtype))


def train_input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    b, s = shape.global_batch, shape.seq_len
    specs: Dict[str, Any] = {"labels": _sds((b, s), jnp.int32)}
    if cfg.is_encdec:
        specs["enc_embeddings"] = _sds(
            (b, s // cfg.enc_seq_divisor, cfg.d_model), cfg.dtype)
        specs["tokens"] = _sds((b, s), jnp.int32)
    elif cfg.frontend:  # vlm/audio decoder-only: precomputed embeddings
        specs["embeddings"] = _sds((b, s, cfg.d_model), cfg.dtype)
        if cfg.rope_variant == "mrope":
            specs["positions"] = _sds((b, s, 3), jnp.int32)
    else:
        specs["tokens"] = _sds((b, s), jnp.int32)
    return specs


def prefill_input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    specs = train_input_specs(cfg, shape)
    specs.pop("labels")
    return specs


def decode_input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    """Decode = one new token against a cache of seq_len.  Returns
    {"cache": <abstract cache pytree>, "token": (B,), "position": (B,)}."""
    b = shape.global_batch
    cache = abstract_cache(cfg, shape)
    return {"cache": cache,
            "token": _sds((b,), jnp.int32),
            "position": _sds((b,), jnp.int32)}


def abstract_cache(cfg: ArchConfig, shape: ShapeConfig, policy=None):
    """Cache ShapeDtypeStructs via eval_shape over the prefill path.

    ``policy`` (a ``PrecisionPolicy``) changes the cache *structure*:
    int8 KV caches come back as Int8KV pairs of structs.  The abstract
    params stay float — cache layout depends only on the policy.
    """
    params = abstract_params(cfg)
    pre_specs = prefill_input_specs(cfg, shape)
    fns = model_fns(cfg)

    def prefill(p, inputs):
        return fns.forward_prefill(cfg, p, inputs, policy)

    _, cache = jax.eval_shape(prefill, params, pre_specs)
    return cache


def input_specs(cfg: ArchConfig, shape: ShapeConfig) -> Dict[str, Any]:
    if shape.kind == "train":
        return train_input_specs(cfg, shape)
    if shape.kind == "prefill":
        return prefill_input_specs(cfg, shape)
    if shape.kind == "decode":
        return decode_input_specs(cfg, shape)
    raise ValueError(shape.kind)


# ---------------------------------------------------------------------------
# Concrete synthetic inputs (smoke tests / examples) — same shapes as specs
# ---------------------------------------------------------------------------
def synthetic_inputs(cfg: ArchConfig, shape: ShapeConfig, key: jax.Array):
    specs = (train_input_specs(cfg, shape) if shape.is_train
             else prefill_input_specs(cfg, shape))
    out = {}
    for name, sds in specs.items():
        key, sub = jax.random.split(key)
        if name in ("tokens", "labels"):
            out[name] = jax.random.randint(sub, sds.shape, 0,
                                           cfg.vocab_size, jnp.int32)
        elif name == "positions":
            pos = jnp.broadcast_to(
                jnp.arange(sds.shape[1], dtype=jnp.int32)[None, :, None],
                sds.shape)
            out[name] = pos
        else:
            out[name] = jax.random.normal(sub, sds.shape, jnp.float32) \
                .astype(sds.dtype) * 0.1
    return out


# Logical-axis annotations for inputs, consumed by the dryrun/sharding layer.
def input_logical_axes(cfg: ArchConfig, shape: ShapeConfig):
    if shape.kind == "decode":
        return None  # handled via cache sharding rules in launch/dryrun.py
    axes = {}
    names = (train_input_specs(cfg, shape) if shape.is_train
             else prefill_input_specs(cfg, shape)).keys()
    for name in names:
        if name in ("tokens", "labels"):
            axes[name] = ("act_batch", "act_seq")
        elif name == "positions":
            axes[name] = ("act_batch", "act_seq", None)
        elif name in ("embeddings", "enc_embeddings"):
            axes[name] = ("act_batch", "act_seq", "act_dmodel")
    return axes
