"""Parameter specs: one declaration per tensor → init / abstract / sharding.

Every backbone parameter is declared once as a ``ParamSpec`` (shape +
logical axes + initializer).  From the spec tree we derive:

* ``init_params``     — concrete fp32 params (PRNG-keyed),
* ``abstract_params`` — ShapeDtypeStruct pytree (dry-run: no allocation),
* ``logical_axes``    — pytree of logical-axis tuples for the sharding policy.

Stacked-layer leading axes carry the logical name "layers" (never sharded)
so every backbone lowers to grouped ``lax.scan``s with O(1)-in-depth HLO.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.arch import ArchConfig


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    logical: Tuple[Optional[str], ...]
    init: str = "normal"          # normal|zeros|ones|a_log|dt_bias|conv
    scale: float = 0.02
    dtype: str = "float32"

    def stack(self, n: int, axis_name: str = "layers") -> "ParamSpec":
        return dataclasses.replace(
            self, shape=(n,) + self.shape, logical=(axis_name,) + self.logical)


SpecTree = Dict[str, object]  # nested dict of ParamSpec


def _norm(d: int) -> ParamSpec:
    return ParamSpec((d,), (None,), init="zeros")


def attn_specs(cfg: ArchConfig) -> SpecTree:
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.n_heads * hd, cfg.n_kv_heads * hd
    return {
        "wq": ParamSpec((d, nq), ("p_dmodel", "p_heads")),
        "wk": ParamSpec((d, nkv), ("p_dmodel", "p_kv_heads")),
        "wv": ParamSpec((d, nkv), ("p_dmodel", "p_kv_heads")),
        "wo": ParamSpec((nq, d), ("p_heads", "p_dmodel")),
    }


def mlp_specs(cfg: ArchConfig) -> SpecTree:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((d, f), ("p_dmodel", "p_ff")),
        "w_up": ParamSpec((d, f), ("p_dmodel", "p_ff")),
        "w_down": ParamSpec((f, d), ("p_ff", "p_ff_in")),
    }


def moe_specs(cfg: ArchConfig) -> SpecTree:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    return {
        "router": ParamSpec((d, e), ("p_dmodel", None)),
        "w_gate": ParamSpec((e, d, f), ("p_experts", "p_dmodel", "p_ff")),
        "w_up": ParamSpec((e, d, f), ("p_experts", "p_dmodel", "p_ff")),
        "w_down": ParamSpec((e, f, d), ("p_experts", "p_ff", "p_ff_in")),
    }


def mamba1_specs(cfg: ArchConfig) -> SpecTree:
    d, di, ds, k = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.d_conv
    dt_rank = max(d // 16, 1)
    return {
        "in_proj": ParamSpec((d, 2 * di), ("p_dmodel", "p_dinner")),
        "conv_w": ParamSpec((k, di), ("p_conv", "p_dinner"), init="conv"),
        "conv_b": ParamSpec((di,), ("p_dinner",), init="zeros"),
        "x_dt": ParamSpec((di, dt_rank), ("p_dinner", None)),
        "dt_proj": ParamSpec((dt_rank, di), (None, "p_dinner"), scale=0.1),
        "dt_bias": ParamSpec((di,), ("p_dinner",), init="dt_bias"),
        "wb": ParamSpec((di, ds), ("p_dinner", "p_state")),
        "wc": ParamSpec((di, ds), ("p_dinner", "p_state")),
        "a_log": ParamSpec((di, ds), ("p_dinner", "p_state"), init="a_log"),
        "d_skip": ParamSpec((di,), ("p_dinner",), init="ones"),
        "out_proj": ParamSpec((di, d), ("p_dinner", "p_dmodel")),
    }


def mamba2_specs(cfg: ArchConfig) -> SpecTree:
    d, di, ds, k = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.d_conv
    nh = cfg.resolved_ssm_heads
    return {
        "in_proj": ParamSpec((d, 2 * di), ("p_dmodel", "p_dinner")),
        "conv_w": ParamSpec((k, di), ("p_conv", "p_dinner"), init="conv"),
        "conv_b": ParamSpec((di,), ("p_dinner",), init="zeros"),
        "wb": ParamSpec((d, ds), ("p_dmodel", "p_state")),
        "wc": ParamSpec((d, ds), ("p_dmodel", "p_state")),
        "dt_w": ParamSpec((d, nh), ("p_dmodel", None)),
        "dt_bias": ParamSpec((nh,), (None,), init="dt_bias"),
        "a_log": ParamSpec((nh,), (None,), init="a_log"),
        "d_skip": ParamSpec((nh,), (None,), init="ones"),
        "gate_norm": ParamSpec((di,), ("p_dinner",), init="zeros"),
        "out_proj": ParamSpec((di, d), ("p_dinner", "p_dmodel")),
    }


def dense_block_specs(cfg: ArchConfig) -> SpecTree:
    return {"attn_norm": _norm(cfg.d_model), "attn": attn_specs(cfg),
            "mlp_norm": _norm(cfg.d_model), "mlp": mlp_specs(cfg)}


def moe_block_specs(cfg: ArchConfig) -> SpecTree:
    return {"attn_norm": _norm(cfg.d_model), "attn": attn_specs(cfg),
            "mlp_norm": _norm(cfg.d_model), "moe": moe_specs(cfg)}


def mamba_block_specs(cfg: ArchConfig) -> SpecTree:
    body = mamba2_specs(cfg) if cfg.ssm_variant == "mamba2" else mamba1_specs(cfg)
    return {"norm": _norm(cfg.d_model), "mamba": body}


def encoder_block_specs(cfg: ArchConfig) -> SpecTree:
    return dense_block_specs(cfg)


def decoder_xattn_block_specs(cfg: ArchConfig) -> SpecTree:
    s = dense_block_specs(cfg)
    s["xattn_norm"] = _norm(cfg.d_model)
    s["xattn"] = attn_specs(cfg)
    return s


def _stack_tree(tree: SpecTree, n: int) -> SpecTree:
    return jax.tree.map(lambda s: s.stack(n), tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def layer_pattern(cfg: ArchConfig) -> Dict[str, int]:
    """Static grouping used by both the spec tree and the forward scan."""
    if cfg.family in ("dense", "vlm") and cfg.local_global_ratio > 0:
        r = cfg.local_global_ratio
        n_groups = cfg.n_layers // (r + 1)
        tail = cfg.n_layers - n_groups * (r + 1)
        return {"kind": "local_global", "ratio": r, "n_groups": n_groups,
                "tail_local": tail}
    if cfg.family == "hybrid":
        k = cfg.attn_every
        assert cfg.n_layers % k == 0, (cfg.n_layers, k)
        return {"kind": "hybrid", "group": k, "n_groups": cfg.n_layers // k}
    if cfg.family == "ssm":
        return {"kind": "uniform_ssm", "n_layers": cfg.n_layers}
    if cfg.is_moe:
        return {"kind": "uniform_moe", "n_layers": cfg.n_layers}
    return {"kind": "uniform_dense", "n_layers": cfg.n_layers}


def build_specs(cfg: ArchConfig) -> SpecTree:
    d = cfg.d_model
    vpad = cfg.padded_vocab()
    specs: SpecTree = {
        "embed": ParamSpec((vpad, d), ("p_vocab", "p_dmodel"), scale=0.02),
        "final_norm": _norm(d),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec((vpad, d), ("p_vocab", "p_dmodel"))

    pat = layer_pattern(cfg)
    if pat["kind"] == "uniform_dense":
        specs["blocks"] = _stack_tree(dense_block_specs(cfg), pat["n_layers"])
    elif pat["kind"] == "uniform_moe":
        specs["blocks"] = _stack_tree(moe_block_specs(cfg), pat["n_layers"])
    elif pat["kind"] == "uniform_ssm":
        specs["blocks"] = _stack_tree(mamba_block_specs(cfg), pat["n_layers"])
    elif pat["kind"] == "local_global":
        group = {
            "local": _stack_tree(
                _stack_tree(dense_block_specs(cfg), pat["ratio"]),
                pat["n_groups"]),
            "global": _stack_tree(dense_block_specs(cfg), pat["n_groups"]),
        }
        specs["groups"] = group
        if pat["tail_local"]:
            specs["tail_local"] = _stack_tree(dense_block_specs(cfg),
                                              pat["tail_local"])
    elif pat["kind"] == "hybrid":
        specs["groups"] = _stack_tree(
            _stack_tree(mamba_block_specs(cfg), pat["group"]),
            pat["n_groups"])
        specs["shared_attn"] = dense_block_specs(cfg)  # weights shared
    else:
        raise ValueError(pat)

    if cfg.is_encdec:
        specs["enc_blocks"] = _stack_tree(encoder_block_specs(cfg),
                                          cfg.n_enc_layers)
        specs["enc_final_norm"] = _norm(d)
        # decoder blocks get cross-attention
        specs["blocks"] = _stack_tree(decoder_xattn_block_specs(cfg),
                                      cfg.n_layers)
    return specs


# ---------------------------------------------------------------------------
# Materialization
# ---------------------------------------------------------------------------
def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_one(spec: ParamSpec, key: jax.Array) -> jax.Array:
    dt = jnp.dtype(spec.dtype)
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dt)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dt)
    if spec.init == "a_log":
        ds = spec.shape[-1]
        base = jnp.log(jnp.arange(1, ds + 1, dtype=jnp.float32))
        return jnp.broadcast_to(base, spec.shape).astype(dt)
    if spec.init == "dt_bias":
        # inverse-softplus of dt uniformly in [1e-3, 0.1] (mamba init)
        u = jax.random.uniform(key, spec.shape, jnp.float32,
                               minval=np.log(1e-3), maxval=np.log(0.1))
        dt = jnp.exp(u)
        return (dt + jnp.log(-jnp.expm1(-dt))).astype(jnp.float32)
    if spec.init == "conv":
        fan_in = spec.shape[0]
        return jax.random.uniform(
            key, spec.shape, jnp.float32,
            minval=-(fan_in ** -0.5), maxval=fan_in ** -0.5)
    # default: scaled normal
    return (jax.random.normal(key, spec.shape, jnp.float32)
            * spec.scale).astype(dt)


def init_params(cfg: ArchConfig, key: jax.Array):
    specs = build_specs(cfg)
    leaves, treedef = jax.tree.flatten(specs, is_leaf=_is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(s, k) for s, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(cfg: ArchConfig):
    specs = build_specs(cfg)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, jnp.dtype(s.dtype)),
        specs, is_leaf=_is_spec)


def logical_axes(cfg: ArchConfig):
    specs = build_specs(cfg)
    return jax.tree.map(lambda s: s.logical, specs, is_leaf=_is_spec)


def param_count(cfg: ArchConfig) -> int:
    specs = build_specs(cfg)
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(specs, is_leaf=_is_spec))
