"""Active learning loop (paper C7 / §4.8, Moreau 2022).

The paper's four steps: (1) train on a small labeled subset,
(2) embed all samples with an intermediate layer, (3) reduce to 2D for
the data explorer, (4) label/clean by proximity to labeled clusters.
PCA stands in for UMAP/t-SNE (same role: the explorer projection);
labeling uses distance-to-labeled-centroid with an abstention radius.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


def pca_2d(x: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """(N, D) -> (N, 2) projection + explained-variance ratios."""
    mu = x.mean(axis=0)
    xc = x - mu
    u, s, vt = np.linalg.svd(xc, full_matrices=False)
    proj = xc @ vt[:2].T
    var = (s ** 2) / max((s ** 2).sum(), 1e-12)
    return proj, var[:2]


def embed_dataset(apply_embed: Callable, xs, batch: int = 64) -> np.ndarray:
    outs = []
    for i in range(0, xs.shape[0], batch):
        outs.append(np.asarray(apply_embed(xs[i:i + batch])))
    return np.concatenate(outs, axis=0)


@dataclasses.dataclass
class ProximityLabeler:
    """Nearest-labeled-centroid labeling with abstention."""
    centroids: np.ndarray          # (C, D)
    radii: np.ndarray              # (C,) per-class abstention radius

    @staticmethod
    def fit(emb: np.ndarray, labels: np.ndarray, n_classes: int,
            radius_quantile: float = 0.9) -> "ProximityLabeler":
        cents, radii = [], []
        for c in range(n_classes):
            pts = emb[labels == c]
            ctr = pts.mean(axis=0)
            d = np.linalg.norm(pts - ctr, axis=1)
            cents.append(ctr)
            radii.append(np.quantile(d, radius_quantile) + 1e-9)
        return ProximityLabeler(np.stack(cents), np.asarray(radii))

    def propose(self, emb: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
        """Returns (labels (N,), confident mask (N,)); label -1 = abstain."""
        d = np.linalg.norm(emb[:, None, :] - self.centroids[None], axis=2)
        nearest = d.argmin(axis=1)
        conf = d[np.arange(len(emb)), nearest] <= self.radii[nearest]
        labels = np.where(conf, nearest, -1)
        return labels, conf


def active_learning_round(apply_embed: Callable, xs, labeled_idx: np.ndarray,
                          labels: np.ndarray, n_classes: int
                          ) -> Dict[str, np.ndarray]:
    """One loop iteration: embed everything, fit on the labeled subset,
    propose labels for the rest, and return the 2D explorer view."""
    emb = embed_dataset(apply_embed, xs)
    labeler = ProximityLabeler.fit(emb[labeled_idx], labels[labeled_idx],
                                   n_classes)
    proposed, confident = labeler.propose(emb)
    proposed[labeled_idx] = labels[labeled_idx]
    proj, var = pca_2d(emb)
    return {"proposed": proposed, "confident": confident,
            "projection": proj, "explained_variance": var,
            "embeddings": emb}
