"""Platform registry: every selectable architecture and block, one place.

``--arch`` on any launcher resolves here; the paper's own evaluation
models are registered alongside the assigned LM pool so the platform
treats a 26k-param DS-CNN and a 132B MoE as rows of the same table.
"""
from __future__ import annotations

from typing import Dict, List

from repro import configs
from repro.core.arch import SHAPES, ArchConfig


PAPER_MODELS = ["ds-cnn", "mobilenetv1", "cifar-cnn", "conv1d-stack"]
DSP_BLOCKS = ["mfe", "mfcc", "spectrogram", "raw", "image_norm"]


def list_architectures() -> List[str]:
    return list(configs.ALIASES)


def get_arch(arch_id: str, smoke: bool = False) -> ArchConfig:
    return configs.get_smoke(arch_id) if smoke else configs.get(arch_id)


def list_shapes() -> List[str]:
    return list(SHAPES)


def describe() -> Dict[str, object]:
    out = {}
    for arch in list_architectures():
        cfg = configs.get(arch)
        out[arch] = {
            "family": cfg.family, "layers": cfg.n_layers,
            "d_model": cfg.d_model, "heads": cfg.n_heads,
            "kv_heads": cfg.n_kv_heads, "d_ff": cfg.d_ff,
            "vocab": cfg.vocab_size,
            "experts": cfg.n_experts or None,
            "ssm": cfg.ssm_variant or None,
        }
    return out
