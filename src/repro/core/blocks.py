"""Impulse blocks (paper C1): the composable pipeline units.

An Edge Impulse project is an ordered block graph: input → DSP block(s)
→ learn block → output.  Here a block is a small adapter pairing a
config with init/apply functions, so the Impulse can train, evaluate,
quantize, estimate, and deploy any combination — including the
LM-family backbones (their "DSP" position is the tokenizer/embedding
pass-through; see DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.dsp import blocks as dsp_blocks
from repro.models import kws


@dataclasses.dataclass(frozen=True)
class DSPBlock:
    """Wraps a stateless dsp.blocks.* feature extractor."""
    impl: Any

    @property
    def name(self) -> str:
        return self.impl.name

    def apply(self, raw: jax.Array) -> jax.Array:
        return self.impl(raw)

    def feature_shape(self, input_shape) -> Tuple[int, ...]:
        if isinstance(input_shape, int):
            return self.impl.feature_shape(input_shape)
        return self.impl.feature_shape(input_shape)

    def hyperparams(self) -> Dict[str, Any]:
        return self.impl.hyperparams()


@dataclasses.dataclass(frozen=True)
class LearnBlock:
    """Wraps a model family: cfg + init(key, input_shape) + apply."""
    cfg: Any
    init_fn: Callable
    apply_fn: Callable

    @property
    def name(self) -> str:
        return self.cfg.name

    def init(self, key, input_shape):
        return self.init_fn(self.cfg, key, input_shape)

    def apply(self, params, feats):
        return self.apply_fn(self.cfg, params, feats)


# ---------------------------------------------------------------------------
# registry of stock blocks (paper's preset architectures, §4.3) +
# user extensibility (paper §4.9: custom processing / learning blocks)
# ---------------------------------------------------------------------------
_DSP_REGISTRY: Dict[str, Any] = {
    "mfe": dsp_blocks.MFEBlock,
    "mfcc": dsp_blocks.MFCCBlock,
    "spectrogram": dsp_blocks.SpectrogramBlock,
    "raw": dsp_blocks.RawBlock,
    "image_norm": dsp_blocks.ImageNormBlock,
}

_LEARN_REGISTRY: Dict[str, Tuple[Any, Callable, Callable]] = {
    "ds-cnn": (kws.DSCNNConfig, kws.dscnn_init, kws.dscnn_apply),
    "mobilenetv1": (kws.MobileNetV1Config, kws.mobilenetv1_init,
                    kws.mobilenetv1_apply),
    "cifar-cnn": (kws.CifarCNNConfig, kws.cifar_cnn_init,
                  kws.cifar_cnn_apply),
    "conv1d-stack": (kws.Conv1DStackConfig, kws.conv1d_stack_init,
                     kws.conv1d_stack_apply),
}


def register_dsp_block(kind: str, impl_cls) -> None:
    """Custom DSP block (paper §4.9).  ``impl_cls(**hp)`` must provide
    ``name``, ``__call__``, ``feature_shape`` and ``hyperparams``."""
    _DSP_REGISTRY[kind] = impl_cls


def register_learn_block(kind: str, cfg_cls, init_fn, apply_fn) -> None:
    """Custom learn block (paper §4.9): cfg dataclass + init + apply."""
    _LEARN_REGISTRY[kind] = (cfg_cls, init_fn, apply_fn)


def make_dsp_block(kind: str, **hp) -> DSPBlock:
    if kind not in _DSP_REGISTRY:
        raise ValueError(f"unknown dsp block {kind!r}; "
                         f"known: {sorted(_DSP_REGISTRY)}")
    return DSPBlock(_DSP_REGISTRY[kind](**hp))


def make_learn_block(kind: str, **hp) -> LearnBlock:
    if kind not in _LEARN_REGISTRY:
        raise ValueError(f"unknown learn block {kind!r}; "
                         f"known: {sorted(_LEARN_REGISTRY)}")
    cfg_cls, init_fn, apply_fn = _LEARN_REGISTRY[kind]
    return LearnBlock(cfg_cls(**hp), init_fn, apply_fn)
