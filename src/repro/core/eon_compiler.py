"""EON Compiler analogue (paper C4): interpreter-less AOT deployment.

Edge Impulse's EON Compiler generates C++ that calls kernels directly,
deleting the TFLM graph interpreter.  The JAX analogue of that
interpreter is the trace + op-by-op dispatch layer: the deployment
artifact here is a **serialized XLA executable** (``jax.export``) that
runs with zero Python tracing / dispatch per call, plus its static
resource report — the exact RAM/flash story of Table 4 transposed to
(HBM, executable bytes).

``benchmarks/table4_memory.py`` measures both modes on CPU: eager
(op-by-op dispatch ≙ interpreter) vs AOT executable (≙ EON).
"""
from __future__ import annotations

import dataclasses
import pickle
import time
from pathlib import Path
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import export as jax_export


def normalize_cost_analysis(cost) -> Dict[str, float]:
    """``compiled.cost_analysis()`` returns a dict on some jax versions
    and a per-device list of dicts on others; normalize to one dict."""
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else None
    return cost or {}


@dataclasses.dataclass
class CompiledArtifact:
    name: str
    serialized: bytes                  # portable executable blob
    input_specs: Any
    memory: Dict[str, int]
    flops: float
    compile_time_s: float

    @property
    def artifact_bytes(self) -> int:
        return len(self.serialized)

    def save(self, path: Path) -> None:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_bytes(pickle.dumps(self))

    @staticmethod
    def load(path: Path) -> "CompiledArtifact":
        return pickle.loads(Path(path).read_bytes())

    def rehydrate(self) -> Callable:
        """Deserialize into a callable that never re-traces."""
        exported = jax_export.deserialize(self.serialized)
        return jax.jit(exported.call)


def compile_fn(fn: Callable, *abstract_args, name: str = "fn",
               static_fn_args: Optional[Dict] = None) -> CompiledArtifact:
    """AOT lower + compile + serialize ``fn(*args)``."""
    t0 = time.time()
    jfn = jax.jit(fn)
    exported = jax_export.export(jfn)(*abstract_args)
    blob = exported.serialize()
    lowered = jfn.lower(*abstract_args)
    compiled = lowered.compile()
    mem = compiled.memory_analysis()
    cost = normalize_cost_analysis(compiled.cost_analysis())
    dt = time.time() - t0
    return CompiledArtifact(
        name=name, serialized=blob, input_specs=abstract_args,
        memory={
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        flops=float(cost.get("flops", 0.0)),
        compile_time_s=dt)


def compile_impulse(impulse, batch_size: int = 1,
                    int8: bool = False) -> CompiledArtifact:
    """Deploy an Impulse: one executable covering DSP + NN end-to-end."""
    if isinstance(impulse.input_shape, int):
        raw_shape = (batch_size, impulse.input_shape)
    else:
        raw_shape = (batch_size,) + tuple(impulse.input_shape)
    raw = jax.ShapeDtypeStruct(raw_shape, jnp.float32)

    if int8:
        assert impulse.qparams is not None
        from repro.core.quantize import fake_quant_params
        frozen = fake_quant_params(impulse.qparams)
    else:
        frozen = impulse.params

    def deploy(x):
        return impulse.learn.apply(frozen, impulse.dsp.apply(x))

    return compile_fn(deploy, raw,
                      name=f"{impulse.dsp.name}+{impulse.learn.name}"
                           f"{'+int8' if int8 else ''}")


def compile_serve_decode(cfg, params, *, slots: int, capacity: int,
                         rules=None, mesh=None, policy=None,
                         pool_blocks: Optional[int] = None,
                         block_size: Optional[int] = None
                         ) -> CompiledArtifact:
    """Serve-from-artifact hook (paper C4, end-to-end): AOT-compile the
    continuous-batching decode step into a ``CompiledArtifact`` so the
    server's hot loop runs the same kind of serialized executable we
    "deploy" — zero Python tracing per token.

    ``slots`` is the engine's decode batch (slot count), ``capacity`` the
    per-slot KV row length (max prompt + max generation budget).
    ``policy`` (``PrecisionPolicy``) lowers the int8 variant: QTensor
    params and an Int8KV cache.  The artifact's static resource report
    carries the KV-cache HBM footprint of both precisions so the deploy
    decision can read the delta without compiling twice — Table 4's
    RAM/flash story transposed to the serving tier.

    The decode signature is ``(params, cache, token, position, kv_len)``
    — with pad-free chunked admission a cache row's index equals its
    entry's absolute position, so the old separate ``write_idx`` operand
    is gone; ``kv_len`` (slots,) is the scheduler's exact per-slot fill
    (``position + 1``; 0 = idle or mid-prefill slot, whose row the step
    neither reads nor writes).

    ``pool_blocks`` compiles the **paged** variant instead: the cache is
    the paged pool (``kvcache.abstract_paged_cache``) and the signature
    grows the per-slot block table — ``(params, cache, token, position,
    kv_len, block_table)`` with ``block_table`` (slots, capacity // BS)
    int32.  The resource report then prices the pool per block
    (``kv_block_bytes``/``kv_pool_blocks``) so the deploy decision can
    read live-KV HBM at any target occupancy, not just the worst case.
    """
    from repro.serve.kvcache import (abstract_decode_cache,
                                     abstract_paged_cache,
                                     decode_cache_nbytes, kv_block_size,
                                     kv_pool_block_bytes)
    from repro.serve.serve_step import (make_paged_decode_step,
                                        make_slot_decode_step)

    paged = pool_blocks is not None
    step = (make_paged_decode_step(cfg, rules=rules, mesh=mesh,
                                   policy=policy) if paged
            else make_slot_decode_step(cfg, rules=rules, mesh=mesh,
                                       policy=policy))
    params_abs = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(jnp.shape(x), jnp.asarray(x).dtype),
        params)
    vec = jax.ShapeDtypeStruct((slots,), jnp.int32)
    suffix = ""
    if policy is not None and policy.weights == "int8":
        suffix = "-int8"
    if paged:
        bs = block_size or kv_block_size(capacity)
        cache_abs = abstract_paged_cache(cfg, slots, capacity,
                                         pool_blocks, policy, bs)
        table = jax.ShapeDtypeStruct((slots, capacity // bs), jnp.int32)
        art = compile_fn(
            step, params_abs, cache_abs, vec, vec, vec, table,
            name=f"{cfg.name}-decode-b{slots}-s{capacity}"
                 f"-paged{pool_blocks}x{bs}{suffix}")
        art.memory["kv_block_bytes"] = kv_pool_block_bytes(cfg, capacity,
                                                           policy, bs)
        art.memory["kv_pool_blocks"] = pool_blocks
    else:
        cache_abs = abstract_decode_cache(cfg, slots, capacity, policy)
        art = compile_fn(
            step, params_abs, cache_abs, vec, vec, vec,
            name=f"{cfg.name}-decode-b{slots}-s{capacity}{suffix}")
    art.memory["kv_cache_bytes"] = decode_cache_nbytes(cache_abs)
    art.memory["kv_cache_bytes_float"] = (
        art.memory["kv_cache_bytes"] if suffix == ""
        else decode_cache_nbytes(
            abstract_paged_cache(cfg, slots, capacity, pool_blocks, None,
                                 block_size)
            if paged else abstract_decode_cache(cfg, slots, capacity,
                                                None)))
    art.memory["param_bytes"] = decode_cache_nbytes(params_abs)
    return art


def measure_dispatch_overhead(fn: Callable, *args, iters: int = 20
                              ) -> Dict[str, float]:
    """Interpreter-vs-EON microbenchmark: eager dispatch vs AOT call."""
    # eager (op-by-op "interpreter" path)
    with jax.disable_jit():
        fn(*args)  # warm
        t0 = time.perf_counter()
        for _ in range(iters):
            jax.block_until_ready(fn(*args))
        eager = (time.perf_counter() - t0) / iters

    jfn = jax.jit(fn)
    jax.block_until_ready(jfn(*args))  # compile
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(jfn(*args))
    aot = (time.perf_counter() - t0) / iters
    return {"eager_us": eager * 1e6, "aot_us": aot * 1e6,
            "speedup": eager / max(aot, 1e-12)}
