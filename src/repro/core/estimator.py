"""Static resource estimation (paper C2 / §4.4).

Edge Impulse predicts latency / RAM / flash per *target device* before
deployment (Renode + device benchmarks).  Two target families here:

* **MCU targets** (the paper's Table 1 boards) — analytic model:
  latency = MACs / effective-MACs-per-second (per-board constant),
  RAM    = peak activation working set (+ interpreter arena overhead),
  flash  = weight bytes (+ runtime code size).
  The interpreter-vs-EON split reproduces Table 4's structure: the EON
  path drops the interpreter arena factor and most runtime code.

* **TPU pod targets** — the dry-run roofline (roofline/model.py) is the
  estimator; this module just adapts its reports into the same
  ResourceEstimate interface so the tuner can treat a Cortex-M4 and a
  256-chip pod as two rows of the same target table.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class MCUTarget:
    name: str
    clock_hz: float
    ram_bytes: int
    flash_bytes: int
    # effective multiply-accumulates per cycle (CMSIS-NN-ish int8 vs float)
    macs_per_cycle_int8: float
    macs_per_cycle_float: float
    # DSP throughput: samples processed per cycle in the MFE/MFCC path
    dsp_samples_per_cycle: float


# Paper Table 1 boards.  MAC/cycle and DSP-throughput constants are
# FITTED from the paper's own Table 2 KWS row (treating the DS-CNN as
# ~11.4 MMACs): e.g. nano int8 322.71 ms @ 64 MHz → 0.55 MAC/cycle.
# The fit then PREDICTS the other tasks/boards — validated in
# benchmarks/table2_inference_times.py.
TARGETS: Dict[str, MCUTarget] = {
    "nano33ble": MCUTarget("Arduino Nano 33 BLE Sense (Cortex-M4 64MHz)",
                           64e6, 256 * 1024, 1024 * 1024,
                           macs_per_cycle_int8=0.55,
                           macs_per_cycle_float=0.062,
                           dsp_samples_per_cycle=0.00177),
    "esp32": MCUTarget("ESP-EYE (Tensilica LX6 160MHz)",
                       160e6, 8 * 1024 * 1024, 4 * 1024 * 1024,
                       macs_per_cycle_int8=0.23,
                       macs_per_cycle_float=0.11,
                       dsp_samples_per_cycle=0.00033),
    "rp2040": MCUTarget("Raspberry Pi Pico (Cortex-M0+ 133MHz)",
                        133e6, 264 * 1024, 16 * 1024 * 1024,
                        macs_per_cycle_int8=0.077,
                        macs_per_cycle_float=0.015,
                        dsp_samples_per_cycle=0.0002),
}


@dataclasses.dataclass
class ResourceEstimate:
    target: str
    dsp_latency_ms: float
    nn_latency_ms: float
    ram_kb: float
    flash_kb: float
    fits: bool
    detail: Dict[str, Any] = dataclasses.field(default_factory=dict)

    @property
    def total_latency_ms(self) -> float:
        return self.dsp_latency_ms + self.nn_latency_ms


# ---------------------------------------------------------------------------
# analytic counters
# ---------------------------------------------------------------------------
def count_macs(apply_fn: Callable, params, feats_shape: Tuple[int, ...]
               ) -> int:
    """MACs of the NN by tracing the jaxpr and summing dot/conv ops."""
    feats = jax.ShapeDtypeStruct((1,) + tuple(feats_shape), jnp.float32)
    jaxpr = jax.make_jaxpr(lambda p, f: apply_fn(p, f))(params, feats)
    macs = 0

    def visit(jx):
        nonlocal macs
        for eqn in jx.eqns:
            if eqn.primitive.name == "dot_general":
                out = eqn.outvars[0].aval
                dn = eqn.params["dimension_numbers"]
                lhs = eqn.invars[0].aval
                k = 1
                for idx in dn[0][0]:
                    k *= lhs.shape[idx]
                macs += int(np.prod(out.shape)) * k
            elif eqn.primitive.name == "conv_general_dilated":
                out = eqn.outvars[0].aval
                rhs = eqn.invars[1].aval
                groups = eqn.params.get("feature_group_count", 1)
                k_per_out = int(np.prod(rhs.shape[:-1])) // max(groups, 1)
                macs += int(np.prod(out.shape)) * k_per_out
            for sub in eqn.params.values():
                if hasattr(sub, "jaxpr"):
                    visit(sub.jaxpr)
    visit(jaxpr.jaxpr)
    return macs


def peak_activation_bytes(apply_fn: Callable, params,
                          feats_shape: Tuple[int, ...],
                          dtype_bytes: int = 4) -> int:
    """Peak working set ≈ largest producer+consumer buffer pair (the
    two-arena model TFLM planning uses)."""
    feats = jax.ShapeDtypeStruct((1,) + tuple(feats_shape), jnp.float32)
    jaxpr = jax.make_jaxpr(lambda p, f: apply_fn(p, f))(params, feats)
    sizes = [int(np.prod(feats.shape)) * dtype_bytes]

    def visit(jx):
        for eqn in jx.eqns:
            for ov in eqn.outvars:
                if hasattr(ov.aval, "shape"):
                    sizes.append(int(np.prod(ov.aval.shape)) * dtype_bytes)
            for sub in eqn.params.values():
                if hasattr(sub, "jaxpr"):
                    visit(sub.jaxpr)
    visit(jaxpr.jaxpr)
    sizes.sort(reverse=True)
    return sizes[0] + (sizes[1] if len(sizes) > 1 else 0)


def param_bytes(params, int8: bool = False) -> int:
    total = 0
    for leaf in jax.tree.leaves(params):
        if int8 and leaf.ndim >= 2:
            total += leaf.size + 4 * leaf.shape[-1]   # int8 + scales
        else:
            total += leaf.size * 4
    return total


# runtime footprints (flash code + RAM arena factor), fitted to Table 4
RUNTIME = {
    "tflm": {"flash_code": 48 * 1024, "ram_factor": 1.35,
             "ram_fixed": 8 * 1024},
    "eon": {"flash_code": 14 * 1024, "ram_factor": 1.08,
            "ram_fixed": 2 * 1024},
}


def estimate_mcu(target: str, *, macs: int, dsp_samples: int,
                 weight_bytes: int, act_bytes: int, engine: str = "eon",
                 int8: bool = True) -> ResourceEstimate:
    t = TARGETS[target]
    rt = RUNTIME[engine]
    mac_rate = (t.macs_per_cycle_int8 if int8 else t.macs_per_cycle_float) \
        * t.clock_hz
    nn_ms = macs / mac_rate * 1e3
    dsp_ms = dsp_samples / (t.dsp_samples_per_cycle * t.clock_hz) * 1e3
    act = act_bytes if not int8 else act_bytes // 4 + 2048
    ram = act * rt["ram_factor"] + rt["ram_fixed"]
    flash = weight_bytes + rt["flash_code"]
    fits = ram <= t.ram_bytes and flash <= t.flash_bytes
    return ResourceEstimate(
        target=target, dsp_latency_ms=dsp_ms, nn_latency_ms=nn_ms,
        ram_kb=ram / 1024, flash_kb=flash / 1024, fits=fits,
        detail={"macs": macs, "engine": engine, "int8": int8})


def estimate_impulse(impulse, target: str, *, engine: str = "eon",
                     int8: bool = True) -> ResourceEstimate:
    """Estimate a whole Impulse (DSP + NN) for an MCU target."""
    feats_shape = impulse.dsp.feature_shape(impulse.input_shape)
    macs = count_macs(impulse.learn.apply, impulse.params, feats_shape)
    act = peak_activation_bytes(impulse.learn.apply, impulse.params,
                                feats_shape)
    wb = param_bytes(impulse.params, int8=int8)
    n_samples = (impulse.input_shape if isinstance(impulse.input_shape, int)
                 else int(np.prod(impulse.input_shape)))
    return estimate_mcu(target, macs=macs, dsp_samples=n_samples,
                        weight_bytes=wb, act_bytes=act, engine=engine,
                        int8=int8)


def pod_estimate_from_report(report_row: Dict[str, Any]) -> ResourceEstimate:
    """Adapt a dry-run roofline row into the common interface."""
    t_total = max(report_row["t_compute_s"],
                  report_row.get("t_memory_min_s",
                                 report_row["t_memory_s"]),
                  report_row["t_collective_s"])
    return ResourceEstimate(
        target=f"tpu-v5e-pod-{report_row['mesh']}",
        dsp_latency_ms=0.0, nn_latency_ms=t_total * 1e3,
        ram_kb=report_row["hbm_gib"] * 1024 * 1024,
        flash_kb=0.0, fits=report_row["fits_hbm"],
        detail=dict(report_row))
