"""The Impulse (paper C1): DSP block + learn block as one trainable,
quantizable, deployable unit — the end-to-end object every other
platform stage (tuner, estimator, compiler, calibration) consumes.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.blocks import DSPBlock, LearnBlock
from repro.core import quantize as qz
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update


@dataclasses.dataclass
class Impulse:
    dsp: DSPBlock
    learn: LearnBlock
    input_shape: Any                     # samples (audio) or (H, W, C)
    params: Optional[Any] = None
    qparams: Optional[qz.QuantizedParams] = None

    # ------------------------------------------------------------------
    def init(self, key) -> "Impulse":
        feat_shape = self.dsp.feature_shape(self.input_shape)
        self.params = self.learn.init(key, feat_shape)
        return self

    def features(self, raw: jax.Array) -> jax.Array:
        return self.dsp.apply(raw)

    def logits(self, raw: jax.Array, params=None) -> jax.Array:
        feats = self.features(raw)
        return self.learn.apply(params if params is not None else self.params,
                                feats)

    def logits_int8(self, raw: jax.Array) -> jax.Array:
        """Quantized inference path (paper C5): DSP stays float, the NN
        runs int8 — matching the platform's deployment split."""
        assert self.qparams is not None, "run quantize() first"
        feats = self.features(raw)
        fq = qz.fake_quant_params(self.qparams)
        return self.learn.apply(fq, feats)

    # ------------------------------------------------------------------
    def loss_fn(self, params, raw, labels):
        logits = self.learn.apply(params, self.features(raw))
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, labels[:, None], axis=1).mean()
        acc = (logits.argmax(-1) == labels).mean()
        return nll, {"loss": nll, "acc": acc}

    def fit(self, train_data, *, epochs: int = 5, batch_size: int = 32,
            lr: float = 1e-3, key=None, eval_data=None,
            log_every: int = 0) -> Dict[str, Any]:
        """Minimal in-memory training loop for platform-scale (KWS-size)
        models; pod-scale training goes through train/trainer.py."""
        key = key if key is not None else jax.random.key(0)
        if self.params is None:
            self.init(key)
        xs, ys = train_data
        n = xs.shape[0]
        opt_cfg = AdamWConfig(lr=lr, weight_decay=0.0, grad_clip=1.0)
        opt_state = adamw_init(self.params)

        @jax.jit
        def step(params, opt_state, bx, by):
            (loss, metrics), grads = jax.value_and_grad(
                self.loss_fn, has_aux=True)(params, bx, by)
            params, opt_state, om = adamw_update(grads, opt_state, params,
                                                 opt_cfg)
            return params, opt_state, {**metrics, **om}

        history = []
        params = self.params
        rng = np.random.RandomState(0)
        for ep in range(epochs):
            order = rng.permutation(n)
            ep_loss, ep_acc, nb = 0.0, 0.0, 0
            # include the tail partial batch: platform-scale datasets are
            # tiny, so dropping it costs a large fraction of the steps
            for i in range(0, n, batch_size):
                idx = order[i:i + batch_size]
                params, opt_state, m = step(params, opt_state, xs[idx],
                                            ys[idx])
                ep_loss += float(m["loss"])
                ep_acc += float(m["acc"])
                nb += 1
            rec = {"epoch": ep, "loss": ep_loss / max(nb, 1),
                   "acc": ep_acc / max(nb, 1)}
            if eval_data is not None:
                rec["val_acc"] = float(self.evaluate(params, *eval_data))
            history.append(rec)
            if log_every and ep % log_every == 0:
                print(rec)
        self.params = params
        return {"history": history, "final": history[-1] if history else {}}

    def evaluate(self, params, xs, ys, batch_size: int = 64) -> float:
        correct, total = 0, 0
        for i in range(0, xs.shape[0], batch_size):
            logits = self.learn.apply(params, self.features(
                xs[i:i + batch_size]))
            correct += int((logits.argmax(-1) == ys[i:i + batch_size]).sum())
            total += int(logits.shape[0])
        return correct / max(total, 1)

    def confusion_matrix(self, xs, ys, n_classes: int) -> np.ndarray:
        preds = np.asarray(self.logits(xs).argmax(-1))
        cm = np.zeros((n_classes, n_classes), np.int64)
        for t, p in zip(np.asarray(ys), preds):
            cm[t, p] += 1
        return cm

    # ------------------------------------------------------------------
    def quantize(self, calib_raw: jax.Array) -> "Impulse":
        """Post-training int8 quantization calibrated on sample data."""
        feats = self.features(calib_raw)
        self.qparams = qz.quantize_params(
            self.params, calib_fn=lambda p: self.learn.apply(p, feats))
        return self

    def int8_accuracy(self, xs, ys, batch_size: int = 64) -> float:
        correct = 0
        for i in range(0, xs.shape[0], batch_size):
            logits = self.logits_int8(xs[i:i + batch_size])
            correct += int((logits.argmax(-1) == ys[i:i + batch_size]).sum())
        return correct / xs.shape[0]
