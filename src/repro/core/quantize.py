"""int8 quantization (paper C5): PTQ + QAT fake-quant, Jacob et al. 2017.

Weights: per-output-channel symmetric int8 (the last axis is treated as
the output-channel axis, matching this repo's (in, out) weight layout).
Activations: per-tensor affine — calibrated ranges would come from
representative data; ``quantize_params`` stores weight quant only (the
paper's "full int8" NN path keeps DSP in float, same as we do).

``fake_quant_params`` returns float params that went through the
quantize→dequantize round trip: bit-faithful int8 numerics on any
backend, and the serving path pairs with ``kernels/int8_matmul`` on TPU.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class QuantizedParams:
    q: Any           # pytree of int8 arrays (or passthrough float leaves)
    scales: Any      # matching pytree of f32 scales (None = not quantized)
    meta: Dict[str, Any]


def _quant_leaf(w: jax.Array):
    """Per-output-channel symmetric int8 for >=2D float leaves."""
    if w.ndim < 2 or not jnp.issubdtype(w.dtype, jnp.floating):
        return w, None
    axes = tuple(range(w.ndim - 1))
    amax = jnp.max(jnp.abs(w), axis=axes, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant_leaf(q, scale):
    if scale is None:
        return q
    return q.astype(jnp.float32) * scale


def quantize_params(params, calib_fn: Optional[Callable] = None
                    ) -> QuantizedParams:
    leaves, treedef = jax.tree.flatten(params)
    qs, ss = [], []
    n_q, total_bytes, q_bytes = 0, 0, 0
    for leaf in leaves:
        q, s = _quant_leaf(leaf)
        qs.append(q)
        ss.append(s)
        total_bytes += leaf.size * leaf.dtype.itemsize
        if s is not None:
            n_q += 1
            q_bytes += q.size + int(np.prod(s.shape)) * 4
        else:
            q_bytes += leaf.size * leaf.dtype.itemsize
    meta = {"n_quantized": n_q, "float_bytes": total_bytes,
            "int8_bytes": q_bytes,
            "compression": total_bytes / max(q_bytes, 1)}
    return QuantizedParams(jax.tree.unflatten(treedef, qs),
                           jax.tree.unflatten(treedef, ss), meta)


def fake_quant_params(qp: QuantizedParams):
    return jax.tree.map(
        lambda q, s: _dequant_leaf(q, s),
        qp.q, qp.scales,
        is_leaf=lambda x: x is None)


def quantization_error(params, qp: QuantizedParams) -> float:
    fq = fake_quant_params(qp)
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, fq)
    return max(jax.tree.leaves(errs))


# ---------------------------------------------------------------------------
# QAT: straight-through-estimator fake quant for training
# ---------------------------------------------------------------------------
def fake_quant_ste(w: jax.Array) -> jax.Array:
    """Quantize-dequantize with identity gradient (STE)."""
    q, s = _quant_leaf(w)
    if s is None:
        return w
    wq = _dequant_leaf(q, s)
    return w + jax.lax.stop_gradient(wq - w)


def qat_params(params):
    """Apply STE fake quant to every quantizable leaf (wrap a loss with
    this for quantization-aware training)."""
    return jax.tree.map(fake_quant_ste, params)


# ---------------------------------------------------------------------------
# Activation quantization helpers (per-tensor affine)
# ---------------------------------------------------------------------------
def calibrate_activation(x: jax.Array) -> Dict[str, float]:
    lo = float(jnp.min(x))
    hi = float(jnp.max(x))
    scale = max(hi - lo, 1e-8) / 255.0
    zero_point = int(round(-lo / scale)) - 128
    return {"scale": scale, "zero_point": zero_point}


def quant_activation(x: jax.Array, c: Dict[str, float]) -> jax.Array:
    q = jnp.round(x / c["scale"]) + c["zero_point"]
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def dequant_activation(q: jax.Array, c: Dict[str, float]) -> jax.Array:
    return (q.astype(jnp.float32) - c["zero_point"]) * c["scale"]
