"""int8 quantization (paper C5): PTQ + QAT fake-quant, Jacob et al. 2017.

Weights: per-output-channel symmetric int8 (the last axis is treated as
the output-channel axis, matching this repo's (in, out) weight layout).
Activations: per-tensor affine — calibrated ranges would come from
representative data; ``quantize_params`` stores weight quant only (the
paper's "full int8" NN path keeps DSP in float, same as we do).

``fake_quant_params`` returns float params that went through the
quantize→dequantize round trip: bit-faithful int8 numerics on any
backend, and the serving path pairs with ``kernels/int8_matmul`` on TPU.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, NamedTuple, Optional

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# PrecisionPolicy: the single knob the serving stack threads end-to-end
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class PrecisionPolicy:
    """How params, activations, and the KV cache are represented.

    ``weights``      "float" | "int8"  — int8 wraps projection weights in
                     ``QTensor`` (per-output-channel symmetric int8).
    ``activations``  "dynamic" | "calibrated" — dynamic quantizes each
                     matmul input per row from its own amax; calibrated
                     uses a ``QTensor.amax`` recorded from representative
                     batches (``AmaxObserver``), falling back to dynamic
                     where no amax was attached.
    ``kv_cache``     "float" | "int8" — int8 stores decode caches as
                     ``Int8KV`` (int8 values + per-entry/per-head f32
                     scales).
    ``compute``      "native" | "fake_quant" — native runs the int8
                     kernels; fake_quant runs the quantize→dequantize
                     round trip in float (bit-faithful reference: the
                     serving tier's token-exactness oracle).
    """
    weights: str = "float"
    activations: str = "dynamic"
    kv_cache: str = "float"
    compute: str = "native"

    def __post_init__(self):
        assert self.weights in ("float", "int8"), self.weights
        assert self.activations in ("dynamic", "calibrated"), self.activations
        assert self.kv_cache in ("float", "int8"), self.kv_cache
        assert self.compute in ("native", "fake_quant"), self.compute


FLOAT = PrecisionPolicy()
INT8 = PrecisionPolicy(weights="int8", kv_cache="int8")
INT8_FAKEQUANT = dataclasses.replace(INT8, compute="fake_quant")

_POLICIES = {"float": FLOAT, "int8": INT8,
             "int8_fakequant": INT8_FAKEQUANT}


def policy_for(name) -> PrecisionPolicy:
    """Resolve a CLI-level precision name (or pass a policy through)."""
    if isinstance(name, PrecisionPolicy):
        return name
    if name not in _POLICIES:
        raise ValueError(f"unknown precision {name!r}; "
                         f"one of {sorted(_POLICIES)}")
    return _POLICIES[name]


class QTensor(NamedTuple):
    """A quantized weight: int8 values + per-output-channel f32 scales.

    ``q`` is (..., K, N) int8, ``scale`` (..., N) f32 (leading dims are
    stacked layers, sliced off by ``lax.scan``).  ``amax`` optionally
    carries a calibrated input-activation amax for this matmul site
    (scalar or per-layer (L,)); None means dynamic activation ranges.
    """
    q: jax.Array
    scale: jax.Array
    amax: Optional[jax.Array] = None


class Int8KV(NamedTuple):
    """An int8 KV-cache tensor: values (..., B, S, H, D) int8 + one f32
    scale per cache entry per head, shape (..., B, S, H)."""
    q: jax.Array
    scale: jax.Array


# jax.export serializes pytree defs by name: register both quantized
# containers so int8 decode steps round-trip as CompiledArtifacts.
try:
    from jax import export as _jax_export
    _jax_export.register_namedtuple_serialization(
        QTensor, serialized_name="repro.quantize.QTensor")
    _jax_export.register_namedtuple_serialization(
        Int8KV, serialized_name="repro.quantize.Int8KV")
except (ImportError, AttributeError):  # pragma: no cover - older jax
    pass


@dataclasses.dataclass
class QuantizedParams:
    q: Any           # pytree of int8 arrays (or passthrough float leaves)
    scales: Any      # matching pytree of f32 scales (None = not quantized)
    meta: Dict[str, Any]


def _quant_leaf(w: jax.Array):
    """Per-output-channel symmetric int8 for >=2D float leaves."""
    if w.ndim < 2 or not jnp.issubdtype(w.dtype, jnp.floating):
        return w, None
    axes = tuple(range(w.ndim - 1))
    amax = jnp.max(jnp.abs(w), axis=axes, keepdims=True)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w / scale), -127, 127).astype(jnp.int8)
    return q, scale.astype(jnp.float32)


def _dequant_leaf(q, scale):
    if scale is None:
        return q
    return q.astype(jnp.float32) * scale


def quantize_params(params, calib_fn: Optional[Callable] = None
                    ) -> QuantizedParams:
    leaves, treedef = jax.tree.flatten(params)
    qs, ss = [], []
    n_q, total_bytes, q_bytes = 0, 0, 0
    for leaf in leaves:
        q, s = _quant_leaf(leaf)
        qs.append(q)
        ss.append(s)
        total_bytes += leaf.size * leaf.dtype.itemsize
        if s is not None:
            n_q += 1
            q_bytes += q.size + int(np.prod(s.shape)) * 4
        else:
            q_bytes += leaf.size * leaf.dtype.itemsize
    meta = {"n_quantized": n_q, "float_bytes": total_bytes,
            "int8_bytes": q_bytes,
            "compression": total_bytes / max(q_bytes, 1)}
    return QuantizedParams(jax.tree.unflatten(treedef, qs),
                           jax.tree.unflatten(treedef, ss), meta)


def fake_quant_params(qp: QuantizedParams):
    return jax.tree.map(
        lambda q, s: _dequant_leaf(q, s),
        qp.q, qp.scales,
        is_leaf=lambda x: x is None)


def quantization_error(params, qp: QuantizedParams) -> float:
    fq = fake_quant_params(qp)
    errs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, fq)
    return max(jax.tree.leaves(errs))


# ---------------------------------------------------------------------------
# QAT: straight-through-estimator fake quant for training
# ---------------------------------------------------------------------------
def fake_quant_ste(w: jax.Array) -> jax.Array:
    """Quantize-dequantize with identity gradient (STE)."""
    q, s = _quant_leaf(w)
    if s is None:
        return w
    wq = _dequant_leaf(q, s)
    return w + jax.lax.stop_gradient(wq - w)


def qat_params(params):
    """Apply STE fake quant to every quantizable leaf (wrap a loss with
    this for quantization-aware training)."""
    return jax.tree.map(fake_quant_ste, params)


# ---------------------------------------------------------------------------
# Dynamic activation quantization (per-row symmetric — the serving path)
# ---------------------------------------------------------------------------
def quant_dynamic(x: jax.Array, amax: Optional[jax.Array] = None):
    """Symmetric int8 per-row quantization of a matmul input.

    x: (..., K) float.  Each row (the last-axis vector entering the
    contraction) gets its own scale from its amax, so the int8 matmul's
    per-row × per-channel dequant is exact.  ``amax`` (broadcastable to
    x.shape[:-1]) substitutes a calibrated range for the observed one.
    Returns (q int8 (..., K), scale f32 (...,)).
    """
    x32 = x.astype(jnp.float32)
    if amax is None:
        row_amax = jnp.max(jnp.abs(x32), axis=-1)
    else:
        row_amax = jnp.broadcast_to(
            jnp.asarray(amax, jnp.float32), x32.shape[:-1])
    scale = jnp.maximum(row_amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x32 / scale[..., None]), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def fake_quant_dynamic(x: jax.Array,
                       amax: Optional[jax.Array] = None) -> jax.Array:
    """Quantize→dequantize round trip of ``quant_dynamic`` in float —
    bit-faithful simulation of the int8 activation path."""
    q, scale = quant_dynamic(x, amax)
    return q.astype(jnp.float32) * scale[..., None]


# ---------------------------------------------------------------------------
# KV-cache quantization (per-entry/per-head vector scales)
# ---------------------------------------------------------------------------
def quant_kv(x: jax.Array) -> Int8KV:
    """Quantize a KV tensor (..., H, D): one symmetric scale per (entry,
    head) vector of length D."""
    x32 = x.astype(jnp.float32)
    amax = jnp.max(jnp.abs(x32), axis=-1)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(x32 / scale[..., None]), -127, 127)
    return Int8KV(q.astype(jnp.int8), scale.astype(jnp.float32))


def dequant_kv(kv: Int8KV, dtype=jnp.float32) -> jax.Array:
    return (kv.q.astype(jnp.float32) * kv.scale[..., None]).astype(dtype)


def maybe_quant_kv(policy: Optional[PrecisionPolicy], x: jax.Array):
    """Apply the policy's KV-cache representation to a float KV tensor:
    Int8KV (native), quant→dequant float (fake_quant), or passthrough."""
    if policy is None or policy.kv_cache != "int8":
        return x
    kv = quant_kv(x)
    if policy.compute == "fake_quant":
        return dequant_kv(kv, x.dtype)
    return kv


# ---------------------------------------------------------------------------
# Model-param quantization for the serving path (QTensor pytree)
# ---------------------------------------------------------------------------
# Param sub-trees whose 2D+ leaves feed ops.quant_matmul.  MoE expert
# banks and SSM dynamics keep float (their einsum dispatch never routes
# through the dense matmul entry point); embed/unembed stay float so
# logits keep full precision.
QUANT_SCOPES = ("attn", "mlp", "xattn")


def _leaf_qtensor(w: jax.Array) -> QTensor:
    """Per-output-channel symmetric int8 over the contraction axis (-2),
    keeping per-layer scales for stacked (L, K, N) leaves."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)), axis=-2)
    scale = jnp.maximum(amax, 1e-8) / 127.0
    q = jnp.clip(jnp.round(w / scale[..., None, :]), -127, 127)
    return QTensor(q.astype(jnp.int8), scale.astype(jnp.float32))


def quantize_model_params(params, policy: PrecisionPolicy = INT8):
    """Wrap every projection weight consumed by ``ops.quant_matmul`` in a
    ``QTensor``.  Leaves outside QUANT_SCOPES (embeddings, norms, MoE
    banks, SSM dynamics) pass through untouched."""
    if policy.weights != "int8":
        return params

    def wrap(path, leaf):
        in_scope = any(getattr(k, "key", None) in QUANT_SCOPES
                       for k in path)
        if (in_scope and leaf.ndim >= 2
                and jnp.issubdtype(leaf.dtype, jnp.floating)):
            return _leaf_qtensor(leaf)
        return leaf

    return jax.tree_util.tree_map_with_path(wrap, params)


def attach_act_amax(qparams, amax_by_scope: Dict[str, float]):
    """Attach calibrated activation amax values to QTensor sites, keyed
    by their innermost scope/leaf name (e.g. {"wq": 3.1, "w_down": 8.2}
    or coarser {"attn": 3.5}).  Unmatched sites keep dynamic ranges.

    The amax is broadcast to the leaf's stacked prefix (``q.shape[:-2]``)
    so ``lax.scan`` over stacked layer params slices it alongside the
    weight pair; a per-layer array of that shape passes through as-is.
    """
    def attach(path, leaf):
        if not isinstance(leaf, QTensor):
            return leaf
        for k in reversed(path):
            name = getattr(k, "key", None)
            if name in amax_by_scope:
                amax = jnp.broadcast_to(
                    jnp.asarray(amax_by_scope[name], jnp.float32),
                    leaf.q.shape[:-2])
                return leaf._replace(amax=amax)
        return leaf

    return jax.tree_util.tree_map_with_path(
        attach, qparams, is_leaf=lambda x: isinstance(x, QTensor))


@dataclasses.dataclass
class AmaxObserver:
    """Running activation-amax over representative batches (paper C5's
    calibration step).  ``momentum=None`` tracks the running max;
    otherwise an EMA, which is robust to outlier batches."""
    momentum: Optional[float] = None
    amax: Optional[float] = None

    def update(self, x: jax.Array) -> float:
        cur = float(jnp.max(jnp.abs(x)))
        if self.amax is None:
            self.amax = cur
        elif self.momentum is None:
            self.amax = max(self.amax, cur)
        else:
            self.amax = self.momentum * self.amax + (1 - self.momentum) * cur
        return self.amax


def calibrate_amax(batches, momentum: Optional[float] = None) -> float:
    """Fold representative batches into one calibrated amax."""
    obs = AmaxObserver(momentum=momentum)
    for x in batches:
        obs.update(x)
    assert obs.amax is not None, "no calibration batches given"
    return obs.amax


# ---------------------------------------------------------------------------
# Activation quantization helpers (per-tensor affine)
# ---------------------------------------------------------------------------
def calibrate_activation(x: jax.Array) -> Dict[str, float]:
    lo = float(jnp.min(x))
    hi = float(jnp.max(x))
    scale = max(hi - lo, 1e-8) / 255.0
    zero_point = int(round(-lo / scale)) - 128
    return {"scale": scale, "zero_point": zero_point}


def quant_activation(x: jax.Array, c: Dict[str, float]) -> jax.Array:
    q = jnp.round(x / c["scale"]) + c["zero_point"]
    return jnp.clip(q, -128, 127).astype(jnp.int8)


def dequant_activation(q: jax.Array, c: Dict[str, float]) -> jax.Array:
    return (q.astype(jnp.float32) - c["zero_point"]) * c["scale"]
