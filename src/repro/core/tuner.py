"""EON Tuner (paper C3 / §4.7): AutoML over the joint (DSP × NN) space
under hard target-hardware constraints.

The paper's method, faithfully: **random search + a cheap heuristic
screen** — sample configurations, predict their resources with the
static estimator (C2), discard constraint violators *before* spending
any training, then train the survivors briefly and rank.  (The paper
lists Bayesian/Hyperband as future work; the random+heuristic baseline
is the shipped algorithm.)

Two instantiations of the same loop:
* ``EONTuner``      — MCU targets: (DSP hyperparams × conv stacks) under
                      RAM/flash/latency budgets.  Reproduces Table 3.
* ``PodConfigTuner``— TPU pods: (sharding strategy × microbatch × remat)
                      under the 16 GiB HBM budget, scored by the dry-run
                      roofline.  Must run inside the dry-run process
                      (512 host devices) — see launch/tune.py.
"""
from __future__ import annotations

import dataclasses
import itertools
import random as pyrandom
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import numpy as np

from repro.core import estimator as est
from repro.core.blocks import make_dsp_block, make_learn_block
from repro.core.impulse import Impulse


@dataclasses.dataclass
class Candidate:
    dsp_kind: str
    dsp_hp: Dict[str, Any]
    model_kind: str
    model_hp: Dict[str, Any]
    estimate: Optional[est.ResourceEstimate] = None
    accuracy: Optional[float] = None
    trained: bool = False

    def describe(self) -> str:
        d = ", ".join(f"{v}" for v in self.dsp_hp.values())
        m = ", ".join(f"{k}={v}" for k, v in self.model_hp.items()
                      if k != "n_classes")
        return f"{self.dsp_kind}({d}) + {self.model_kind}({m})"


DEFAULT_KWS_SPACE = {
    "dsp": [
        ("mfe", {"frame_s": [0.02, 0.032, 0.05],
                 "stride_s": [0.01, 0.016, 0.02, 0.025],
                 "n_mels": [32, 40]}),
        ("mfcc", {"frame_s": [0.02, 0.05],
                  "stride_s": [0.01, 0.025],
                  "n_mels": [32, 40], "n_coeffs": [10, 13]}),
    ],
    "model": [
        ("conv1d-stack", {"n_blocks": [2, 3, 4],
                          "ch_first": [16, 32],
                          "ch_last": [32, 64, 128, 256]}),
    ],
}


class EONTuner:
    def __init__(self, *, input_samples: int, n_classes: int,
                 target: str = "nano33ble", engine: str = "eon",
                 int8: bool = True,
                 max_ram_kb: Optional[float] = None,
                 max_flash_kb: Optional[float] = None,
                 max_latency_ms: Optional[float] = None,
                 space: Dict = None, seed: int = 0):
        self.input_samples = input_samples
        self.n_classes = n_classes
        self.target = target
        self.engine = engine
        self.int8 = int8
        t = est.TARGETS[target]
        self.max_ram_kb = max_ram_kb or t.ram_bytes / 1024
        self.max_flash_kb = max_flash_kb or t.flash_bytes / 1024
        self.max_latency_ms = max_latency_ms
        self.space = space or DEFAULT_KWS_SPACE
        self.rng = pyrandom.Random(seed)

    # -- phase 1: random sampling -------------------------------------
    def sample(self, n: int) -> List[Candidate]:
        out = []
        for _ in range(n):
            dsp_kind, dsp_grid = self.rng.choice(self.space["dsp"])
            model_kind, model_grid = self.rng.choice(self.space["model"])
            dsp_hp = {k: self.rng.choice(v) for k, v in dsp_grid.items()}
            model_hp = {k: self.rng.choice(v) for k, v in model_grid.items()}
            model_hp["n_classes"] = self.n_classes
            if model_hp.get("ch_last", 0) < model_hp.get("ch_first", 0):
                model_hp["ch_last"] = model_hp["ch_first"]
            out.append(Candidate(dsp_kind, dsp_hp, model_kind, model_hp))
        return out

    def build(self, cand: Candidate) -> Impulse:
        imp = Impulse(make_dsp_block(cand.dsp_kind, **cand.dsp_hp),
                      make_learn_block(cand.model_kind, **cand.model_hp),
                      input_shape=self.input_samples)
        return imp.init(jax.random.key(self.rng.randrange(2 ** 31)))

    # -- phase 2: heuristic screen (the paper's cheap estimate) --------
    def screen(self, cands: Sequence[Candidate]) -> List[Candidate]:
        keep = []
        for c in cands:
            imp = self.build(c)
            c.estimate = est.estimate_impulse(imp, self.target,
                                              engine=self.engine,
                                              int8=self.int8)
            ok = (c.estimate.ram_kb <= self.max_ram_kb
                  and c.estimate.flash_kb <= self.max_flash_kb)
            if self.max_latency_ms is not None:
                ok = ok and c.estimate.total_latency_ms <= self.max_latency_ms
            if ok:
                keep.append(c)
        return keep

    # -- phase 3: train survivors + rank -------------------------------
    def evaluate(self, cands: Sequence[Candidate], train_data, val_data, *,
                 epochs: int = 3, batch_size: int = 32) -> List[Candidate]:
        for c in cands:
            imp = self.build(c)
            imp.fit(train_data, epochs=epochs, batch_size=batch_size)
            c.accuracy = imp.evaluate(imp.params, *val_data)
            c.trained = True
        return sorted(cands, key=lambda c: -(c.accuracy or 0.0))

    def search(self, train_data, val_data, *, n_samples: int = 12,
               epochs: int = 3) -> List[Candidate]:
        cands = self.sample(n_samples)
        survivors = self.screen(cands)
        return self.evaluate(survivors, train_data, val_data, epochs=epochs)


# ---------------------------------------------------------------------------
# Pod-scale instantiation: the same loop over distribution knobs
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class PodCandidate:
    strategy: str
    n_micro: Optional[int]
    remat: str
    report: Optional[Dict[str, Any]] = None

    def key(self):
        return (self.strategy, self.n_micro, self.remat)


class PodConfigTuner:
    """Random search + screen over (strategy × microbatch × remat) for one
    (arch × shape × mesh) cell, scored by roofline_fraction under the
    HBM constraint.  ``evaluator`` is launch.dryrun.run_cell."""

    def __init__(self, evaluator: Callable, *, arch: str, shape: str,
                 multi_pod: bool = False, hbm_gib: float = 16.0,
                 seed: int = 0):
        self.evaluator = evaluator
        self.arch = arch
        self.shape = shape
        self.multi_pod = multi_pod
        self.hbm_gib = hbm_gib
        self.rng = pyrandom.Random(seed)

    def space(self, train: bool) -> List[PodCandidate]:
        strategies = ["tp", "tp_sp", "cp"]
        micros = [None, 8, 16, 32] if train else [None]
        remats = ["full", "dots"] if train else ["none"]
        cands = [PodCandidate(s, m, r) for s, m, r
                 in itertools.product(strategies, micros, remats)]
        self.rng.shuffle(cands)
        return cands

    def search(self, *, n_samples: int = 8) -> List[PodCandidate]:
        train = self.shape.startswith("train")
        cands = self.space(train)[:n_samples]
        scored = []
        for c in cands:
            try:
                res = self.evaluator(
                    self.arch, self.shape, multi_pod=self.multi_pod,
                    strategy=c.strategy, n_micro=c.n_micro,
                    remat=c.remat)
            except Exception as e:   # illegal combos are search misses
                res = {"status": "error", "error": str(e)[:300]}
            c.report = res
            scored.append(c)
        ok = [c for c in scored
              if c.report.get("status") == "ok"
              and c.report["memory"]["per_device_hbm_gib"] <= self.hbm_gib]
        return sorted(
            ok, key=lambda c: -c.report["roofline"]["roofline_fraction"])
