"""Project façade (paper §4.9): the programmatic API surface.

Edge Impulse exposes every platform stage via REST so pipelines can be
automated without the Studio GUI.  ``Project`` is that surface in
Python: one object owning the dataset, the impulse, tuning, deployment
and calibration — each method maps 1:1 onto a platform stage, so
`examples/` and third-party code never reach into internals.

    p = Project("kws-demo", workdir)
    p.ingest(samples)                 # data acquisition
    p.set_impulse("mfcc", {...}, "conv1d-stack", {...})
    p.train(epochs=5)                 # ML design & training
    p.test()                          # evaluation
    p.quantize()                      # compression (C5)
    p.estimate("nano33ble")           # estimation (C2)
    p.tune(n_samples=8)               # AutoML (C3)
    p.deploy(out_path)                # conversion & compilation (C4)
"""
from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional

import jax
import numpy as np

from repro.core import estimator as est
from repro.core.blocks import make_dsp_block, make_learn_block
from repro.core.calibration import calibrate
from repro.core.eon_compiler import CompiledArtifact, compile_impulse
from repro.core.impulse import Impulse
from repro.core.tuner import EONTuner
from repro.data.dataset import Dataset, Sample


class Project:
    def __init__(self, name: str, workdir: Optional[Path] = None,
                 n_classes: int = 0, seed: int = 0):
        self.name = name
        self.workdir = Path(workdir) if workdir else None
        self.dataset = Dataset(self.workdir / "data" if self.workdir
                               else None)
        self.n_classes = n_classes
        self.impulse: Optional[Impulse] = None
        self.seed = seed
        self.log: List[Dict[str, Any]] = []

    # -- data acquisition ------------------------------------------------
    def ingest(self, samples: Iterable[Sample], message: str = "") -> str:
        ids = self.dataset.add_many(samples)
        self.n_classes = max(self.n_classes,
                             max((s.label for s in
                                  self.dataset.samples.values()),
                                 default=-1) + 1)
        version = self.dataset.commit(message or f"ingest {len(ids)}")
        self._log("ingest", n=len(ids), version=version)
        return version

    # -- impulse design ----------------------------------------------------
    def set_impulse(self, dsp_kind: str, dsp_hp: Dict, learn_kind: str,
                    learn_hp: Dict) -> Impulse:
        any_sample = next(iter(self.dataset.samples.values()))
        input_shape = (any_sample.data.shape[0]
                       if any_sample.data.ndim == 1
                       else tuple(any_sample.data.shape))
        learn_hp = dict(learn_hp)
        learn_hp.setdefault("n_classes", self.n_classes)
        self.impulse = Impulse(make_dsp_block(dsp_kind, **dsp_hp),
                               make_learn_block(learn_kind, **learn_hp),
                               input_shape=input_shape)
        self.impulse.init(jax.random.key(self.seed))
        self._log("set_impulse", dsp=dsp_kind, model=learn_kind)
        return self.impulse

    # -- train / evaluate --------------------------------------------------
    def train(self, epochs: int = 5, batch_size: int = 16,
              lr: float = 2e-3) -> Dict[str, Any]:
        xs, ys = self.dataset.arrays("train")
        out = self.impulse.fit((np.asarray(xs), np.asarray(ys)),
                               epochs=epochs, batch_size=batch_size, lr=lr)
        self._log("train", **out["final"])
        return out

    def test(self) -> Dict[str, Any]:
        xs, ys = self.dataset.arrays("test")
        acc = self.impulse.evaluate(self.impulse.params,
                                    np.asarray(xs), np.asarray(ys))
        cm = self.impulse.confusion_matrix(np.asarray(xs), np.asarray(ys),
                                           self.n_classes)
        self._log("test", acc=acc)
        return {"accuracy": acc, "confusion": cm.tolist()}

    # -- compression / estimation / deployment ------------------------------
    def quantize(self) -> Dict[str, Any]:
        xs, _ = self.dataset.arrays("train")
        self.impulse.quantize(np.asarray(xs[:16]))
        meta = self.impulse.qparams.meta
        self._log("quantize", compression=meta["compression"])
        return meta

    def estimate(self, target: str, engine: str = "eon",
                 int8: bool = True) -> est.ResourceEstimate:
        e = est.estimate_impulse(self.impulse, target, engine=engine,
                                 int8=int8)
        self._log("estimate", target=target, ram_kb=e.ram_kb,
                  flash_kb=e.flash_kb, latency_ms=e.total_latency_ms)
        return e

    def tune(self, n_samples: int = 8, target: str = "nano33ble",
             epochs: int = 2) -> List:
        any_sample = next(iter(self.dataset.samples.values()))
        tuner = EONTuner(input_samples=int(any_sample.data.shape[0]),
                         n_classes=self.n_classes, target=target,
                         seed=self.seed)
        xtr, ytr = self.dataset.arrays("train")
        xva, yva = self.dataset.arrays("val")
        ranked = tuner.search((np.asarray(xtr), np.asarray(ytr)),
                              (np.asarray(xva), np.asarray(yva)),
                              n_samples=n_samples, epochs=epochs)
        self._log("tune", candidates=n_samples, survivors=len(ranked))
        return ranked

    def deploy(self, path: Optional[Path] = None,
               int8: bool = False) -> CompiledArtifact:
        art = compile_impulse(self.impulse, batch_size=1, int8=int8)
        if path:
            art.save(Path(path))
        self._log("deploy", bytes=art.artifact_bytes, int8=int8)
        return art

    def calibrate_postprocessing(self, scores: np.ndarray,
                                 event_spans, **kw) -> List[Dict]:
        front = calibrate(scores, event_spans, **kw)
        self._log("calibrate", front=len(front))
        return front

    # -- bookkeeping ---------------------------------------------------------
    def _log(self, stage: str, **kw) -> None:
        rec = {"stage": stage, **{k: (float(v) if isinstance(v, (int, float))
                                      else v) for k, v in kw.items()}}
        self.log.append(rec)
        if self.workdir:
            self.workdir.mkdir(parents=True, exist_ok=True)
            (self.workdir / "project_log.json").write_text(
                json.dumps(self.log, indent=1, default=str))

    def summary(self) -> Dict[str, Any]:
        return {"name": self.name, "samples": len(self.dataset),
                "classes": self.n_classes,
                "impulse": (f"{self.impulse.dsp.name}+"
                            f"{self.impulse.learn.name}"
                            if self.impulse else None),
                "stages_run": [r["stage"] for r in self.log]}
