"""Architecture configuration — the single source of truth for a backbone.

Every assigned architecture (and the paper's own KWS/VWW/IC models) is an
``ArchConfig``.  One generic backbone consumes it; layer heterogeneity
(local/global attention, shared attention blocks, MoE) is expressed as a
static layer *pattern* so the whole stack lowers to grouped ``lax.scan``s.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional, Tuple

import jax.numpy as jnp

# Families. "dense"/"moe"/"hybrid"/"ssm" use the decoder-only backbone;
# "audio" uses the encoder-decoder backbone; "vlm" is decoder-only with an
# embedding-injection frontend stub; "cnn" covers the paper's eval models.
FAMILIES = ("dense", "moe", "hybrid", "ssm", "audio", "vlm", "cnn")


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str

    # Transformer trunk.
    n_layers: int = 0
    d_model: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    d_ff: int = 0
    vocab_size: int = 0
    head_dim: int = 0                 # 0 -> d_model // n_heads
    tie_embeddings: bool = False

    # Mixture of experts.
    n_experts: int = 0
    experts_per_tok: int = 0
    capacity_factor: float = 1.25

    # State-space (mamba) blocks.
    ssm_state: int = 0
    ssm_variant: str = ""             # "mamba1" | "mamba2"
    ssm_expand: int = 2
    d_conv: int = 4
    ssm_heads: int = 0                # mamba2 only; 0 -> d_inner // 64
    attn_every: int = 0               # zamba2: shared attn block every k layers

    # Attention pattern.
    sliding_window: int = 0           # >0 enables sliding-window layers
    local_global_ratio: int = 0       # e.g. 5 -> 5 local : 1 global
    rope_variant: str = "rope"        # "rope" | "mrope"
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)  # t/h/w head_dim split

    # Encoder-decoder.
    is_encdec: bool = False
    n_enc_layers: int = 0
    enc_seq_divisor: int = 4          # enc_seq = seq // divisor (conv subsample)

    # Modality frontend stub ("" | "audio" | "vision").
    frontend: str = ""

    norm_eps: float = 1e-5
    dtype: str = "bfloat16"
    vocab_pad_multiple: int = 2048

    # ------------------------------------------------------------------
    def __post_init__(self):
        if self.family not in FAMILIES:
            raise ValueError(f"unknown family {self.family!r}")
        if self.family != "cnn" and self.d_model <= 0:
            raise ValueError(f"{self.name}: d_model must be positive")

    # Derived quantities -------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // max(self.n_heads, 1)

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    @property
    def resolved_ssm_heads(self) -> int:
        if self.ssm_heads:
            return self.ssm_heads
        return max(self.d_inner // 64, 1)

    @property
    def activation_dtype(self):
        return jnp.dtype(self.dtype)

    def padded_vocab(self, multiple: Optional[int] = None) -> int:
        """Vocab rounded up so it shards over the model axis and tiles the MXU.

        Real frameworks (MaxText, Megatron) pad the embedding table; logits
        over pad columns are masked to -inf in the loss.
        """
        if multiple is None:
            multiple = self.vocab_pad_multiple
        if self.vocab_size == 0:
            return 0
        return int(math.ceil(self.vocab_size / multiple) * multiple)

    @property
    def uses_attention(self) -> bool:
        return self.family != "ssm" and self.n_heads > 0

    @property
    def is_moe(self) -> bool:
        return self.n_experts > 0

    @property
    def is_ssm_only(self) -> bool:
        return self.family == "ssm"

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (see DESIGN.md long_500k policy)."""
        if self.family in ("ssm", "hybrid"):
            return True
        # Sliding-window-dominant patterns (gemma3 5:1 local:global).
        return self.sliding_window > 0 and self.local_global_ratio > 0

    # Parameter counting (used by estimator + roofline MODEL_FLOPS) ------
    def param_count(self, active_only: bool = False) -> int:
        """Analytic parameter count of the backbone (embeddings included)."""
        if self.family == "cnn":
            return 0  # CNN configs carry their own count via the model def.
        d, h = self.d_model, self.resolved_head_dim
        n_q = self.n_heads * h
        n_kv = self.n_kv_heads * h
        attn = d * n_q + 2 * d * n_kv + n_q * d  # wq wk wv wo
        mlp_dense = 3 * d * self.d_ff            # SwiGLU: gate, up, down
        per_layer = 0
        if self.family == "ssm":
            per_layer = self._mamba_params()
        elif self.family == "hybrid":
            per_layer = self._mamba_params()
            # Shared attention block amortized over layers it serves.
            shared = attn + mlp_dense
            n_attn = self.n_layers // max(self.attn_every, 1)
            total_shared = shared  # weights are SHARED -> count once
            base = self.n_layers * per_layer + total_shared + n_attn * 0
            emb = self.padded_vocab() * d * (1 if self.tie_embeddings else 2)
            return base + emb
        elif self.is_moe:
            n_e = self.n_experts if not active_only else self.experts_per_tok
            per_layer = attn + n_e * mlp_dense + d * self.n_experts  # + router
        else:
            per_layer = attn + mlp_dense
        n_layers = self.n_layers + (self.n_enc_layers if self.is_encdec else 0)
        total = n_layers * per_layer
        if self.is_encdec:  # decoder cross-attention
            total += self.n_layers * attn
        emb = self.padded_vocab() * d * (1 if self.tie_embeddings else 2)
        return total + emb

    def _mamba_params(self) -> int:
        d, di, ds = self.d_model, self.d_inner, self.ssm_state
        in_proj = d * 2 * di
        conv = self.d_conv * di
        if self.ssm_variant == "mamba2":
            nh = self.resolved_ssm_heads
            extra = d * 2 * nh * ds + nh  # B,C projections folded + A_log per head
        else:
            dt_rank = max(d // 16, 1)
            extra = di * dt_rank + dt_rank * di + di * ds * 2 + di * ds  # dt, B, C, A
        out_proj = di * d
        return in_proj + conv + extra + out_proj

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One input-shape cell (assigned shape set)."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"

    @property
    def is_train(self) -> bool:
        return self.kind == "train"


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524_288, 1, "decode"),
}


def shape_applicable(arch: ArchConfig, shape: ShapeConfig) -> Tuple[bool, str]:
    """Whether an (arch, shape) cell runs — DESIGN.md long_500k policy."""
    if shape.name == "long_500k" and not arch.sub_quadratic:
        return False, "skipped_by_design: pure full-attention arch, long_500k needs sub-quadratic"
    return True, ""
