"""Performance calibration (paper C6 / §4.4, Situnayake 2022).

For streaming event detection, raw per-window model scores must pass a
post-processing chain (score smoothing → threshold → suppression) before
becoming detections.  The paper tunes that chain with a genetic
algorithm and presents configurations trading FAR (false accepts / hour)
against FRR (missed events / events).  Implemented bit-for-bit in that
spirit: NSGA-ish GA with Pareto ranking over (FAR, FRR).
"""
from __future__ import annotations

import dataclasses
import random as pyrandom
from typing import Callable, Dict, List, Sequence, Tuple

import numpy as np


@dataclasses.dataclass(frozen=True)
class PostProcessConfig:
    smooth_window: int        # moving-average over per-window scores
    threshold: float          # detection threshold on smoothed score
    suppression: int          # windows to suppress after a detection

    def mutate(self, rng: pyrandom.Random) -> "PostProcessConfig":
        sw = max(1, self.smooth_window + rng.choice([-2, -1, 0, 1, 2]))
        th = float(np.clip(self.threshold + rng.gauss(0, 0.08), 0.05, 0.99))
        sp = max(0, self.suppression + rng.choice([-3, -1, 0, 1, 3]))
        return PostProcessConfig(sw, th, sp)

    @staticmethod
    def crossover(a: "PostProcessConfig", b: "PostProcessConfig",
                  rng: pyrandom.Random) -> "PostProcessConfig":
        return PostProcessConfig(
            rng.choice([a.smooth_window, b.smooth_window]),
            rng.choice([a.threshold, b.threshold]),
            rng.choice([a.suppression, b.suppression]))


def apply_postprocess(scores: np.ndarray, cfg: PostProcessConfig
                      ) -> np.ndarray:
    """scores: (T,) per-window positive-class probability.
    Returns detection indicator (T,) after smoothing/threshold/suppress."""
    if cfg.smooth_window > 1:
        kernel = np.ones(cfg.smooth_window) / cfg.smooth_window
        sm = np.convolve(scores, kernel, mode="same")
    else:
        sm = scores
    det = np.zeros_like(scores, dtype=bool)
    cooldown = 0
    for t in range(len(scores)):
        if cooldown > 0:
            cooldown -= 1
            continue
        if sm[t] >= cfg.threshold:
            det[t] = True
            cooldown = cfg.suppression
    return det


def far_frr(scores: np.ndarray, event_spans: Sequence[Tuple[int, int]],
            cfg: PostProcessConfig, *, windows_per_hour: float
            ) -> Tuple[float, float]:
    """FAR = false accepts per hour; FRR = fraction of events missed."""
    det = apply_postprocess(scores, cfg)
    in_event = np.zeros(len(scores), dtype=bool)
    for a, b in event_spans:
        in_event[a:b] = True
    false_accepts = int(np.sum(det & ~in_event))
    hits = sum(bool(det[a:b].any()) for a, b in event_spans)
    frr = 1.0 - hits / max(len(event_spans), 1)
    hours = len(scores) / windows_per_hour
    return false_accepts / max(hours, 1e-9), frr


def pareto_front(points: List[Tuple[float, float, PostProcessConfig]]
                 ) -> List[Tuple[float, float, PostProcessConfig]]:
    front = []
    for p in sorted(points, key=lambda p: (p[0], p[1])):
        while front and front[-1][1] >= p[1]:
            front.pop()
        if not front or p[1] < front[-1][1]:
            front.append(p)
    return front


def calibrate(scores: np.ndarray, event_spans: Sequence[Tuple[int, int]], *,
              windows_per_hour: float = 3600.0, generations: int = 12,
              population: int = 24, seed: int = 0
              ) -> List[Dict]:
    """GA search; returns the Pareto-optimal post-processing configs."""
    rng = pyrandom.Random(seed)
    pop = [PostProcessConfig(rng.randint(1, 9),
                             rng.uniform(0.2, 0.95),
                             rng.randint(0, 20))
           for _ in range(population)]
    seen: Dict[PostProcessConfig, Tuple[float, float]] = {}

    def fitness(cfg):
        if cfg not in seen:
            seen[cfg] = far_frr(scores, event_spans, cfg,
                                windows_per_hour=windows_per_hour)
        return seen[cfg]

    for _ in range(generations):
        scored = [(fitness(c), c) for c in pop]
        # Pareto-rank selection: non-dominated first, then crowded tail
        def dominated(a, b):
            return (b[0][0] <= a[0][0] and b[0][1] <= a[0][1]
                    and b[0] != a[0])
        ranked = sorted(
            scored, key=lambda s: (sum(dominated(s, o) for o in scored),
                                   s[0][0] + s[0][1]))
        parents = [c for _, c in ranked[:population // 2]]
        children = []
        while len(children) < population - len(parents):
            a, b = rng.sample(parents, 2)
            child = PostProcessConfig.crossover(a, b, rng)
            if rng.random() < 0.6:
                child = child.mutate(rng)
            children.append(child)
        pop = parents + children

    pts = [(far, frr, cfg) for cfg, (far, frr) in
           ((c, fitness(c)) for c in set(pop) | set(seen))]
    front = pareto_front(pts)
    return [{"far_per_hour": far, "frr": frr,
             "config": dataclasses.asdict(cfg)}
            for far, frr, cfg in front]
