"""Multi-format ingestion (paper §4.1): CSV / JSON / WAV / NPY → Sample.

The platform accepts "CSV, CBOR, JSON, WAV, JPG, or PNG"; this offline
environment covers the text/audio/array formats with stdlib parsers
(wave, csv, json) — image formats would slot in identically behind
``INGESTORS``.
"""
from __future__ import annotations

import csv
import io
import json
import wave
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional

import numpy as np

from repro.data.dataset import Sample


def ingest_csv(path_or_bytes, label: int,
               metadata: Optional[Dict] = None) -> Sample:
    """CSV of one time series: columns are channels, rows are steps."""
    if isinstance(path_or_bytes, (str, Path)):
        text = Path(path_or_bytes).read_text()
    else:
        text = path_or_bytes.decode()
    rows = [[float(v) for v in r] for r in csv.reader(io.StringIO(text))
            if r and not r[0].startswith("#")]
    arr = np.asarray(rows, np.float32)
    if arr.shape[1] == 1:
        arr = arr[:, 0]
    return Sample(arr, label, metadata or {"format": "csv"})


def ingest_json(path_or_bytes, metadata: Optional[Dict] = None) -> Sample:
    """Edge-Impulse-style JSON: {"values": [...], "label": int, ...}."""
    if isinstance(path_or_bytes, (str, Path)):
        obj = json.loads(Path(path_or_bytes).read_text())
    else:
        obj = json.loads(path_or_bytes)
    arr = np.asarray(obj["values"], np.float32)
    meta = {k: v for k, v in obj.items() if k not in ("values", "label")}
    meta.update(metadata or {})
    return Sample(arr, int(obj.get("label", -1)), meta)


def ingest_wav(path_or_bytes, label: int,
               metadata: Optional[Dict] = None) -> Sample:
    if isinstance(path_or_bytes, (str, Path)):
        buf = Path(path_or_bytes).read_bytes()
    else:
        buf = path_or_bytes
    with wave.open(io.BytesIO(buf)) as w:
        n = w.getnframes()
        raw = w.readframes(n)
        width = w.getsampwidth()
        rate = w.getframerate()
    dtype = {1: np.int8, 2: np.int16, 4: np.int32}[width]
    arr = np.frombuffer(raw, dtype).astype(np.float32)
    arr /= float(np.iinfo(dtype).max)
    meta = {"sample_rate": rate, "format": "wav"}
    meta.update(metadata or {})
    return Sample(arr, label, meta)


def ingest_npy(path_or_bytes, label: int,
               metadata: Optional[Dict] = None) -> Sample:
    if isinstance(path_or_bytes, (str, Path)):
        arr = np.load(path_or_bytes)
    else:
        arr = np.load(io.BytesIO(path_or_bytes))
    return Sample(np.asarray(arr, np.float32), label,
                  metadata or {"format": "npy"})


INGESTORS = {".csv": ingest_csv, ".json": ingest_json,
             ".wav": ingest_wav, ".npy": ingest_npy}


def ingest_directory(root: Path, label_from_dir: bool = True
                     ) -> List[Sample]:
    """class-per-subdirectory layout: root/<label_idx>_<name>/file.ext"""
    out: List[Sample] = []
    root = Path(root)
    for sub in sorted(p for p in root.iterdir() if p.is_dir()):
        label = int(sub.name.split("_")[0]) if label_from_dir else -1
        for f in sorted(sub.iterdir()):
            fn = INGESTORS.get(f.suffix)
            if fn is None:
                continue
            if f.suffix == ".json":
                out.append(ingest_json(f, metadata={"path": str(f)}))
            else:
                out.append(fn(f, label, {"path": str(f)}))
    return out
