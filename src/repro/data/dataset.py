"""Versioned dataset store (paper C8 / §4.1, §2.4 data consistency).

Every sample is content-addressed (sha1 of its bytes) and assigned a
deterministic train/val/test split from its hash — adding or removing
samples never reshuffles anyone else's split, which is the paper's
"maintaining train/validation/test splits ... adding or removing
individual samples" operational requirement.  Dataset versions are
manifest files (sample ids + metadata), so checkout/diff is cheap and
the data, not the storage, defines the version.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import time
from pathlib import Path
from typing import Any, Dict, Iterable, List, Optional, Sequence, Tuple

import numpy as np


@dataclasses.dataclass
class Sample:
    data: np.ndarray
    label: int
    metadata: Dict[str, Any] = dataclasses.field(default_factory=dict)
    sample_id: str = ""

    def __post_init__(self):
        if not self.sample_id:
            h = hashlib.sha1()
            h.update(np.ascontiguousarray(self.data).tobytes())
            h.update(str(self.label).encode())
            self.sample_id = h.hexdigest()


def split_of(sample_id: str, val_frac: float = 0.1, test_frac: float = 0.2
             ) -> str:
    """Deterministic split from the content hash."""
    u = int(sample_id[:8], 16) / 0xFFFFFFFF
    if u < test_frac:
        return "test"
    if u < test_frac + val_frac:
        return "val"
    return "train"


class Dataset:
    def __init__(self, root: Optional[Path] = None):
        self.root = Path(root) if root else None
        self.samples: Dict[str, Sample] = {}
        if self.root:
            (self.root / "blobs").mkdir(parents=True, exist_ok=True)
            (self.root / "versions").mkdir(parents=True, exist_ok=True)

    # -- mutation ------------------------------------------------------
    def add(self, sample: Sample) -> str:
        self.samples[sample.sample_id] = sample
        if self.root:
            blob = self.root / "blobs" / f"{sample.sample_id}.npz"
            if not blob.exists():
                np.savez_compressed(
                    blob, data=sample.data, label=sample.label,
                    metadata=json.dumps(sample.metadata))
        return sample.sample_id

    def add_many(self, samples: Iterable[Sample]) -> List[str]:
        return [self.add(s) for s in samples]

    def remove(self, sample_id: str) -> None:
        self.samples.pop(sample_id, None)

    # -- versioning ------------------------------------------------------
    def commit(self, message: str = "") -> str:
        ids = sorted(self.samples)
        h = hashlib.sha1("".join(ids).encode()).hexdigest()[:12]
        if self.root:
            manifest = {
                "version": h, "message": message, "time": time.time(),
                "samples": [
                    {"id": sid, "label": self.samples[sid].label,
                     "split": split_of(sid),
                     "metadata": self.samples[sid].metadata}
                    for sid in ids],
            }
            (self.root / "versions" / f"{h}.json").write_text(
                json.dumps(manifest, indent=1))
        return h

    def checkout(self, version: str) -> "Dataset":
        assert self.root, "versioning requires a rooted dataset"
        manifest = json.loads(
            (self.root / "versions" / f"{version}.json").read_text())
        ds = Dataset(self.root)
        for rec in manifest["samples"]:
            blob = np.load(self.root / "blobs" / f"{rec['id']}.npz",
                           allow_pickle=False)
            ds.samples[rec["id"]] = Sample(
                data=blob["data"], label=int(blob["label"]),
                metadata=json.loads(str(blob["metadata"])),
                sample_id=rec["id"])
        return ds

    def versions(self) -> List[str]:
        if not self.root:
            return []
        return sorted(p.stem for p in (self.root / "versions").glob("*.json"))

    # -- access ----------------------------------------------------------
    def split(self, name: str) -> List[Sample]:
        return [s for sid, s in sorted(self.samples.items())
                if split_of(sid) == name]

    def arrays(self, name: str) -> Tuple[np.ndarray, np.ndarray]:
        part = self.split(name)
        if not part:
            return np.zeros((0,)), np.zeros((0,), np.int32)
        xs = np.stack([s.data for s in part])
        ys = np.asarray([s.label for s in part], np.int32)
        return xs, ys

    def class_counts(self) -> Dict[int, int]:
        out: Dict[int, int] = {}
        for s in self.samples.values():
            out[s.label] = out.get(s.label, 0) + 1
        return out

    def __len__(self) -> int:
        return len(self.samples)
