"""Synthetic data generators: classifiable keyword audio, event streams
for performance calibration, and LM token streams.

Keyword classes are distinct multi-tone chirps in noise — hard enough
that the DSP + model choice matters (the Table 3 sweep separates), easy
enough to train in seconds on CPU.
"""
from __future__ import annotations

from typing import Dict, List, Sequence, Tuple

import numpy as np

from repro.data.dataset import Sample


def keyword_audio(n_per_class: int = 40, n_classes: int = 4,
                  n_samples: int = 16_000, sample_rate: int = 16_000,
                  snr_db: float = 8.0, seed: int = 0) -> List[Sample]:
    rng = np.random.RandomState(seed)
    out: List[Sample] = []
    base_freqs = 300.0 * (1.7 ** np.arange(n_classes))
    t = np.arange(n_samples) / sample_rate
    for c in range(n_classes):
        for i in range(n_per_class):
            f0 = base_freqs[c] * rng.uniform(0.9, 1.1)
            sweep = rng.uniform(-0.3, 0.3)
            sig = np.zeros(n_samples, np.float32)
            # keyword = 3 harmonics with class-specific AM pattern
            env_rate = 2.0 + c * 1.5
            env = 0.5 * (1 + np.sin(2 * np.pi * env_rate * t
                                    + rng.uniform(0, 2 * np.pi)))
            for h, amp in ((1, 1.0), (2, 0.5), (3, 0.25)):
                freq = f0 * h * (1 + sweep * t)
                sig += amp * np.sin(2 * np.pi * freq * t).astype(np.float32)
            sig *= env.astype(np.float32)
            noise = rng.randn(n_samples).astype(np.float32)
            snr = 10 ** (snr_db / 20)
            sig = sig / (np.std(sig) + 1e-6) * snr + noise
            sig /= np.abs(sig).max() + 1e-6
            out.append(Sample(sig.astype(np.float32), c,
                              {"source": "synthetic", "class": int(c),
                               "seed": int(seed), "idx": int(i)}))
    return out


def event_stream(n_windows: int = 20_000, n_events: int = 60,
                 event_len: int = 12, noise: float = 0.18, seed: int = 0
                 ) -> Tuple[np.ndarray, List[Tuple[int, int]]]:
    """Per-window detector scores with ground-truth event spans — the
    performance-calibration input (score ~ high during events + noise)."""
    rng = np.random.RandomState(seed)
    scores = np.clip(rng.rand(n_windows) * noise * 2.4, 0, 1)
    spans = []
    for _ in range(n_events):
        a = rng.randint(0, n_windows - event_len)
        spans.append((a, a + event_len))
        ramp = np.hanning(event_len) * rng.uniform(0.55, 1.0)
        scores[a:a + event_len] = np.maximum(scores[a:a + event_len], ramp)
    # sprinkle confusable distractors
    for _ in range(n_events // 2):
        a = rng.randint(0, n_windows - 4)
        scores[a:a + 3] = np.maximum(scores[a:a + 3],
                                     rng.uniform(0.4, 0.75))
    return scores.astype(np.float32), spans


def token_stream(n_tokens: int, vocab_size: int, seed: int = 0,
                 order: int = 2) -> np.ndarray:
    """Markov token stream — a learnable LM target (loss drops below
    the unigram entropy only if the model actually fits the chain)."""
    rng = np.random.RandomState(seed)
    ctx = vocab_size
    # sparse transition structure: each context prefers 4 successors
    prefer = rng.randint(0, vocab_size, size=(ctx, 4))
    out = np.empty(n_tokens, np.int32)
    state = rng.randint(vocab_size)
    for i in range(n_tokens):
        if rng.rand() < 0.85:
            state = int(prefer[state, rng.randint(4)])
        else:
            state = int(rng.randint(vocab_size))
        out[i] = state
    return out


def lm_batches(tokens: np.ndarray, batch: int, seq: int, seed: int = 0):
    """Yield {tokens, labels} windows forever."""
    rng = np.random.RandomState(seed)
    n = len(tokens) - seq - 1
    while True:
        idx = rng.randint(0, n, size=batch)
        x = np.stack([tokens[i:i + seq] for i in idx])
        y = np.stack([tokens[i + 1:i + seq + 1] for i in idx])
        yield {"tokens": x, "labels": y}
