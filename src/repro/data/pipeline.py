"""Host data pipeline: shuffle → batch → (per-host shard) → prefetch.

At pod scale each host feeds only its addressable shard of the global
batch (``host_shard``); a slow host therefore delays nothing but its own
shard's collective entry — the straggler story is handled at the trainer
level (see train/trainer.py watchdog).
"""
from __future__ import annotations

import queue
import threading
from typing import Callable, Dict, Iterator, Optional

import numpy as np


class BatchPipeline:
    def __init__(self, arrays: Dict[str, np.ndarray], *, batch_size: int,
                 shuffle: bool = True, seed: int = 0, drop_last: bool = True,
                 host_index: int = 0, host_count: int = 1):
        self.arrays = arrays
        n = next(iter(arrays.values())).shape[0]
        assert all(a.shape[0] == n for a in arrays.values())
        self.n = n
        self.batch_size = batch_size
        self.shuffle = shuffle
        self.seed = seed
        self.drop_last = drop_last
        self.host_index = host_index
        self.host_count = host_count
        assert batch_size % host_count == 0

    def epoch(self, epoch_idx: int = 0) -> Iterator[Dict[str, np.ndarray]]:
        order = np.arange(self.n)
        if self.shuffle:
            # same permutation on every host: shard by position
            np.random.RandomState(self.seed + epoch_idx).shuffle(order)
        bs = self.batch_size
        per_host = bs // self.host_count
        lo = self.host_index * per_host
        for i in range(0, self.n - (bs if self.drop_last else 1) + 1, bs):
            idx = order[i:i + bs][lo:lo + per_host]
            yield {k: a[idx] for k, a in self.arrays.items()}

    def forever(self) -> Iterator[Dict[str, np.ndarray]]:
        e = 0
        while True:
            yield from self.epoch(e)
            e += 1


class Prefetcher:
    """Background-thread prefetch (depth-bounded) over any iterator."""

    def __init__(self, it: Iterator, depth: int = 2,
                 transform: Optional[Callable] = None):
        self.it = it
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self.transform = transform
        self._done = object()
        self.thread = threading.Thread(target=self._run, daemon=True)
        self.thread.start()

    def _run(self):
        try:
            for item in self.it:
                if self.transform:
                    item = self.transform(item)
                self.q.put(item)
        finally:
            self.q.put(self._done)

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is self._done:
            raise StopIteration
        return item
