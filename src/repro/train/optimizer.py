"""Optimizers from scratch (no optax in this environment).

States are plain pytrees mapped leaf-for-leaf over params, so they
inherit the params' shardings verbatim — with FSDP param sharding this
is ZeRO-3 optimizer-state sharding for free.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0


def adamw_init(params) -> Dict[str, Any]:
    zeros = lambda p: jnp.zeros_like(p)
    return {"m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32)}


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(l.astype(jnp.float32)))
              for l in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def adamw_update(grads, state, params, cfg: AdamWConfig,
                 lr: jax.Array | float | None = None
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jax.Array]]:
    """Returns (new_params, new_state, metrics)."""
    lr = cfg.lr if lr is None else lr
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-12)) \
        if cfg.grad_clip > 0 else 1.0

    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mhat = m / bc1
        vhat = v / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps)
        if cfg.weight_decay > 0:
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in
           zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_p, new_state, {"grad_norm": gnorm,
                              "lr": jnp.asarray(lr, jnp.float32)}


def sgd_update(grads, state, params, lr: float):
    new_p = jax.tree.map(
        lambda p, g: (p.astype(jnp.float32)
                      - lr * g.astype(jnp.float32)).astype(p.dtype),
        params, grads)
    return new_p, state, {"grad_norm": global_norm(grads)}


def abstract_opt_state(abstract_params):
    zeros = lambda p: jax.ShapeDtypeStruct(p.shape, p.dtype)
    return {"m": jax.tree.map(zeros, abstract_params),
            "v": jax.tree.map(zeros, abstract_params),
            "step": jax.ShapeDtypeStruct((), jnp.int32)}
