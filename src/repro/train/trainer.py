"""Pod-scale training loop: metrics, checkpoint cadence, fault tolerance,
straggler watchdog, best-model restoration (paper §4.3).

Fault model (exercised in tests via injected failures):
* **crash/restart** — the trainer always resumes from the latest *valid*
  checkpoint (atomic writes make partially-written ones invisible);
* **step watchdog** — a step exceeding ``watchdog_factor`` × the median
  step time is logged as a straggler event; after ``max_stragglers``
  consecutive events the trainer requests an elastic rescale
  (launch/elastic.py decides the new mesh);
* **best-model restoration** — the paper lists this among its stable-
  training features: track val loss, restore the best checkpoint at end.
"""
from __future__ import annotations

import dataclasses
import time
from pathlib import Path
from typing import Any, Callable, Dict, Iterator, List, Optional

import jax
import numpy as np

from repro.checkpoint.checkpointer import Checkpointer
from repro.train.schedule import warmup_cosine


@dataclasses.dataclass
class TrainerConfig:
    total_steps: int = 100
    checkpoint_every: int = 20
    eval_every: int = 0
    log_every: int = 10
    keep_checkpoints: int = 3
    watchdog_factor: float = 3.0
    max_stragglers: int = 5
    restore_best: bool = True


class Trainer:
    def __init__(self, train_step: Callable, params, opt_state, *,
                 ckpt_dir: Path, config: TrainerConfig = TrainerConfig(),
                 eval_fn: Optional[Callable] = None):
        self.train_step = train_step
        self.params = params
        self.opt_state = opt_state
        self.cfg = config
        self.ckpt = Checkpointer(ckpt_dir, keep=config.keep_checkpoints)
        self.eval_fn = eval_fn
        self.history: List[Dict[str, float]] = []
        self.step = 0
        self.best = {"loss": float("inf"), "step": -1}
        self.straggler_events = 0
        self.rescale_requested = False

    # ------------------------------------------------------------------
    def maybe_resume(self, shardings=None) -> bool:
        latest = self.ckpt.latest_step()
        if latest is None:
            return False
        (self.params, self.opt_state), extra = self.ckpt.restore(
            (self.params, self.opt_state), latest, shardings)
        self.step = extra.get("step", latest)
        self.best = extra.get("best", self.best)
        return True

    # ------------------------------------------------------------------
    def run(self, batches: Iterator[Dict[str, np.ndarray]],
            fail_at: Optional[int] = None) -> Dict[str, Any]:
        """``fail_at`` simulates a node failure at that step (tests)."""
        step_times: List[float] = []
        while self.step < self.cfg.total_steps:
            batch = next(batches)
            t0 = time.perf_counter()
            self.params, self.opt_state, metrics = self.train_step(
                self.params, self.opt_state, batch)
            jax.block_until_ready(metrics["loss"])
            dt = time.perf_counter() - t0
            self.step += 1

            if fail_at is not None and self.step == fail_at:
                raise RuntimeError(f"injected node failure at step "
                                   f"{self.step}")

            # straggler watchdog
            if len(step_times) >= 5:
                med = float(np.median(step_times[-20:]))
                if dt > self.cfg.watchdog_factor * med:
                    self.straggler_events += 1
                    if self.straggler_events >= self.cfg.max_stragglers:
                        self.rescale_requested = True
                else:
                    self.straggler_events = 0
            step_times.append(dt)

            rec = {"step": self.step,
                   "loss": float(metrics["loss"]),
                   "grad_norm": float(metrics.get("grad_norm", 0.0)),
                   "step_time_s": dt}
            self.history.append(rec)
            if float(metrics["loss"]) < self.best["loss"]:
                self.best = {"loss": float(metrics["loss"]),
                             "step": self.step}
            if self.cfg.log_every and self.step % self.cfg.log_every == 0:
                print(f"step {self.step}: loss={rec['loss']:.4f} "
                      f"gnorm={rec['grad_norm']:.3f} {dt*1e3:.0f}ms")
            if (self.cfg.checkpoint_every
                    and self.step % self.cfg.checkpoint_every == 0):
                self.ckpt.save(self.step, (self.params, self.opt_state),
                               extra={"step": self.step, "best": self.best})
        # final checkpoint + optional best restore
        self.ckpt.save(self.step, (self.params, self.opt_state),
                       extra={"step": self.step, "best": self.best})
        result = {"history": self.history, "best": self.best,
                  "final_loss": self.history[-1]["loss"],
                  "rescale_requested": self.rescale_requested}
        if (self.cfg.restore_best and self.best["step"] > 0
                and self.best["step"] in self.ckpt.all_steps()):
            (self.params, self.opt_state), _ = self.ckpt.restore(
                (self.params, self.opt_state), self.best["step"])
            result["restored_step"] = self.best["step"]
        return result
