"""Gradient compression for the DP all-reduce (distributed-optimization
trick for the 1000+ node posture).

Two schemes with error feedback (residual carried in optimizer-adjacent
state so compression error doesn't bias the trajectory):

* int8: per-leaf symmetric int8 quantization (8x wire bytes vs f32).
* topk: keep the largest-|g| fraction per leaf (sparse all-reduce).

On the SPMD path the quantize→dequantize pair brackets where the gradient
all-reduce happens; byte savings on the wire require the collective to
run on the int8 payload, which XLA SPMD does when the reduce is performed
on the quantized tensor (int8 sum with clipping caveat — we reduce in
int32, see ``compressed_psum``).  The numerics here are bit-faithful to
the deployed scheme either way, which is what training-quality
experiments need.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp


def int8_compress(g: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def int8_decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def topk_mask(g: jax.Array, frac: float) -> jax.Array:
    k = max(int(g.size * frac), 1)
    flat = jnp.abs(g.reshape(-1))
    thresh = jax.lax.top_k(flat, k)[0][-1]
    return (jnp.abs(g) >= thresh).astype(g.dtype)


def compress_grads(grads, residual, scheme: Optional[str],
                   topk_frac: float = 0.01):
    """Apply compression with error feedback.  Returns (grads', residual')."""
    if scheme is None or scheme == "none":
        return grads, residual

    def one(g, r):
        g = g.astype(jnp.float32) + (r if r is not None else 0.0)
        if scheme == "int8":
            q, s = int8_compress(g)
            out = int8_decompress(q, s)
        elif scheme == "topk":
            out = g * topk_mask(g, topk_frac)
        else:
            raise ValueError(scheme)
        return out, g - out

    if residual is None:
        residual = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32),
                                grads)
    pairs = jax.tree.map(one, grads, residual)
    new_g = jax.tree.map(lambda p: p[0], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    new_r = jax.tree.map(lambda p: p[1], pairs,
                         is_leaf=lambda x: isinstance(x, tuple))
    return new_g, new_r


def init_residual(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
