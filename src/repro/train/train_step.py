"""train_step factory: loss → grad-accumulation scan → (compressed)
reduce → AdamW.  Built once per (arch × shape × policy) and AOT-lowered
by both the trainer and the dry-run.
"""
from __future__ import annotations

import functools
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.arch import ArchConfig, ShapeConfig
from repro.models.api import model_fns
from repro.sharding.policy import AxisRules, use_rules
from repro.train import compression as comp
from repro.train.optimizer import AdamWConfig, adamw_update


def make_train_step(cfg: ArchConfig, *, n_microbatch: int = 1,
                    remat: str = "full", rules: Optional[AxisRules] = None,
                    mesh=None, opt: AdamWConfig = AdamWConfig(),
                    grad_compression: Optional[str] = None,
                    lr_from_step: bool = False):
    """Returns train_step(params, opt_state, batch) -> (params', opt', metrics).

    ``batch`` leaves have a leading global-batch dim; with
    ``n_microbatch > 1`` the batch is split and grads are accumulated in
    an ``lax.scan`` (sequential microbatches — the standard memory /
    throughput trade).
    """
    fns = model_fns(cfg)

    def loss_fn(params, micro):
        loss, metrics = fns.forward_train(cfg, params, micro, remat=remat)
        return loss, metrics

    grad_fn = jax.value_and_grad(loss_fn, has_aux=True)

    def _context(fn):
        if rules is None or mesh is None:
            return fn

        @functools.wraps(fn)
        def wrapped(*a, **k):
            with use_rules(rules, mesh):
                return fn(*a, **k)
        return wrapped

    @_context
    def train_step(params, opt_state, batch):
        if n_microbatch > 1:
            micros = jax.tree.map(
                lambda x: x.reshape(n_microbatch, x.shape[0] // n_microbatch,
                                    *x.shape[1:]),
                batch)

            def micro_body(acc, micro):
                (loss, metrics), grads = grad_fn(params, micro)
                acc_g, acc_loss = acc
                acc_g = jax.tree.map(jnp.add, acc_g, grads)
                return (acc_g, acc_loss + loss), None

            zero_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (grads, loss_sum), _ = lax.scan(
                micro_body, (zero_g, jnp.zeros((), jnp.float32)), micros)
            grads = jax.tree.map(lambda g: g / n_microbatch, grads)
            loss = loss_sum / n_microbatch
        else:
            (loss, _), grads = grad_fn(params, batch)

        if grad_compression and grad_compression != "none":
            # caller must init opt_state["residual"] (error feedback)
            residual = opt_state["residual"]
            grads, residual = comp.compress_grads(grads, residual,
                                                  grad_compression)
        else:
            residual = None
        lr = None  # AdamWConfig.lr; schedules handled by the trainer
        new_params, new_opt, om = adamw_update(grads, opt_state, params, opt,
                                               lr)
        if residual is not None:
            new_opt["residual"] = residual
        metrics = {"loss": loss, **om}
        return new_params, new_opt, metrics

    return train_step


def batch_reshape_check(shape: ShapeConfig, n_microbatch: int) -> None:
    if shape.global_batch % n_microbatch:
        raise ValueError(
            f"global_batch {shape.global_batch} % n_microbatch "
            f"{n_microbatch} != 0")
