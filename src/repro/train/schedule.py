"""LR schedules + the paper's "learning rate finding" (§4.3).

Edge Impulse lists learning-rate finding among its stable-training
optimisations; ``lr_finder`` is the standard exponential-sweep variant:
run N probe steps with exponentially increasing lr, pick the lr one
decade below the divergence knee.
"""
from __future__ import annotations

from typing import Callable, List, Tuple

import jax.numpy as jnp
import numpy as np


def warmup_cosine(step, *, base_lr: float, warmup: int, total: int,
                  min_frac: float = 0.1):
    step = jnp.asarray(step, jnp.float32)
    warm = base_lr * step / max(warmup, 1)
    prog = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * (min_frac + (1 - min_frac) * 0.5
                     * (1 + jnp.cos(jnp.pi * prog)))
    return jnp.where(step < warmup, warm, cos)


def constant(step, *, base_lr: float):
    return jnp.asarray(base_lr, jnp.float32)


def lr_finder(step_fn: Callable[[float], float], *,
              lr_min: float = 1e-6, lr_max: float = 1.0,
              n_probe: int = 20, smooth: float = 0.7
              ) -> Tuple[float, List[Tuple[float, float]]]:
    """``step_fn(lr) -> loss`` runs one probe training step at that lr
    (caller resets state between probes or accepts the drift, as the
    classic fastai finder does).  Returns (suggested_lr, curve)."""
    lrs = np.exp(np.linspace(np.log(lr_min), np.log(lr_max), n_probe))
    curve: List[Tuple[float, float]] = []
    ema = None
    best_lr, best_slope = lr_min, 0.0
    prev = None
    for lr in lrs:
        loss = float(step_fn(float(lr)))
        ema = loss if ema is None else smooth * ema + (1 - smooth) * loss
        curve.append((float(lr), ema))
        if prev is not None:
            slope = (ema - prev) / ema
            if slope < best_slope:
                best_slope, best_lr = slope, lr
        prev = ema
        if not np.isfinite(loss) or (curve and ema > 4 * curve[0][1]):
            break  # diverged — stop the sweep
    return float(best_lr / 10 if best_lr > lr_min else best_lr), curve
