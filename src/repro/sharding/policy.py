"""Logical-axis sharding policy (DP / FSDP / TP / EP / SP).

Model code never names mesh axes.  It annotates tensors with *logical*
axis names; a rule table maps logical names to mesh axes.  Swapping the
rule table is how the tuner (core/tuner.py) explores sharding layouts —
the direct analogue of the EON Tuner swapping target-device constraints.

Divisibility is checked against the live mesh: a logical axis whose
dimension does not divide the mapped mesh axes silently falls back to
replication for that dim (e.g. 4 KV heads on a 16-way model axis).  This
makes every policy safe by construction across the heterogeneous
architecture pool.
"""
from __future__ import annotations

import contextlib
import threading
from typing import Dict, Optional, Sequence, Tuple, Union

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

AxisAssignment = Union[None, str, Tuple[str, ...]]
AxisRules = Dict[str, AxisAssignment]

_state = threading.local()


def _current() -> Tuple[Optional[Mesh], Optional[AxisRules]]:
    return getattr(_state, "mesh", None), getattr(_state, "rules", None)


def current_mesh_rules() -> Tuple[Optional[Mesh], Optional[AxisRules]]:
    """Public accessor for layers that need mesh-aware structure (MoE EP)."""
    return _current()


def axis_assignment_size(mesh: Optional[Mesh],
                         assignment: AxisAssignment) -> int:
    if mesh is None or assignment is None:
        return 1
    axes = (assignment,) if isinstance(assignment, str) else assignment
    n = 1
    for a in axes:
        if a in mesh.shape:
            n *= mesh.shape[a]
    return n


@contextlib.contextmanager
def use_rules(rules: AxisRules, mesh: Mesh):
    """Activate a rule table + mesh for ``constrain`` calls underneath."""
    prev = _current()
    _state.mesh, _state.rules = mesh, rules
    try:
        yield
    finally:
        _state.mesh, _state.rules = prev


def _mesh_size(mesh: Mesh, assignment: AxisAssignment) -> int:
    if assignment is None:
        return 1
    if isinstance(assignment, str):
        assignment = (assignment,)
    n = 1
    for a in assignment:
        n *= mesh.shape[a]
    return n


def logical_to_pspec(logical_axes: Sequence[Optional[str]],
                     rules: AxisRules, mesh: Optional[Mesh] = None,
                     shape: Optional[Sequence[int]] = None) -> P:
    """Map logical axis names to a PartitionSpec, dropping non-divisible
    or unknown assignments.  Mesh axes are never assigned twice."""
    spec, used = [], set()
    for i, name in enumerate(logical_axes):
        assignment = rules.get(name) if name is not None else None
        if assignment is None:
            spec.append(None)
            continue
        axes = (assignment,) if isinstance(assignment, str) else tuple(assignment)
        axes = tuple(a for a in axes if a not in used)
        if not axes:
            spec.append(None)
            continue
        if mesh is not None:
            axes = tuple(a for a in axes if a in mesh.shape)
            if not axes:
                spec.append(None)
                continue
            if shape is not None:
                size = 1
                for a in axes:
                    size *= mesh.shape[a]
                if size == 0 or shape[i] % size != 0:
                    spec.append(None)
                    continue
        used.update(axes)
        spec.append(axes[0] if len(axes) == 1 else tuple(axes))
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def constrain(x: jax.Array, logical_axes: Sequence[Optional[str]]) -> jax.Array:
    """with_sharding_constraint via logical names; no-op outside use_rules."""
    mesh, rules = _current()
    if mesh is None or rules is None:
        return x
    spec = logical_to_pspec(logical_axes, rules, mesh, x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def params_pspecs(logical_tree, rules: AxisRules, mesh: Mesh,
                  shapes_tree=None):
    """Map a pytree of logical-axis tuples to NamedShardings.

    ``shapes_tree`` (a matching pytree of array shapes / ShapeDtypeStructs)
    enables the divisibility fallback per leaf.
    """
    if shapes_tree is None:
        return jax.tree.map(
            lambda axes: NamedSharding(
                mesh, logical_to_pspec(axes, rules, mesh)),
            logical_tree, is_leaf=lambda l: isinstance(l, tuple))
    return jax.tree.map(
        lambda axes, s: NamedSharding(
            mesh, logical_to_pspec(axes, rules, mesh,
                                   getattr(s, "shape", s))),
        logical_tree, shapes_tree,
        is_leaf=lambda l: isinstance(l, tuple))


# ---------------------------------------------------------------------------
# Rule tables (the tuner's sharding search space)
# ---------------------------------------------------------------------------
def make_rules(strategy: str = "tp", multi_pod: bool = False,
               decode: bool = False) -> AxisRules:
    """Build a rule table.

    Strategies
    ----------
    tp        : Megatron-style TP over "model" (heads / d_ff / experts /
                vocab), DP over ("pod","data"), FSDP param sharding over
                "data".  Default for head-divisible archs.
    cp        : context parallelism — attention computed with the query
                sequence sharded over "model" (any head count works),
                MLP stays ff-sharded.  Default for archs whose head count
                does not divide the model axis (gemma3: 8H, llama3.2: 24H).
    tp_sp     : tp + sequence-sharded residual stream between blocks
                (Megatron SP — beyond-paper activation-memory lever).
    replicated: no model-axis sharding (debug / tiny models).
    """
    batch_axes: AxisAssignment = ("pod", "data") if multi_pod else ("data",)
    fsdp: AxisAssignment = "data"

    base: AxisRules = {
        # --- parameters ---
        "p_dmodel": fsdp,          # FSDP storage dim
        "p_heads": "model",
        "p_kv_heads": "model",
        "p_ff": "model",
        "p_ff_in": fsdp,           # second dim of down-proj
        "p_vocab": "model",
        "p_experts": "model",
        "p_dinner": "model",
        "p_state": None,
        "p_conv": None,
        "layers": None,
        # --- activations ---
        "act_batch": batch_axes,
        "act_seq": None,
        "act_res_seq": None,   # residual stream between blocks (SP)
        "act_dmodel": None,
        "act_heads": "model",
        "act_kv_heads": "model",
        "act_kv_seq": None,
        "act_ff": "model",
        "act_vocab": "model",
        "act_experts": "model",
        "act_expert_cap": batch_axes,   # EP: capacity dim over the DP axes
        "act_dinner": "model",
        # KV-cache seq storage: sharded over "model" at prefill (a full
        # 32k cache replicated over the model axis costs 16x the HBM),
        # over ("data","model") at decode (flash-decoding).
        "act_cache_seq": "model",
    }
    if strategy == "cp":
        base.update({
            "p_heads": None, "p_kv_heads": None,
            "act_heads": None, "act_kv_heads": None,
            "act_seq": "model",        # queries sharded over model axis
            "act_kv_seq": None,        # K/V gathered (cheap under GQA)
        })
    elif strategy == "tp_sp":
        # Megatron-SP: only the residual stream (norms/adds) is sequence-
        # sharded; QKV/MLP stay head/ff-sharded — GSPMD inserts the
        # all-gather at the projections and reduce-scatters back.
        base.update({"act_res_seq": "model"})
    elif strategy == "replicated":
        for k in list(base):
            if k != "act_batch":
                base[k] = None
    elif strategy != "tp":
        raise ValueError(f"unknown strategy {strategy!r}")

    if decode:
        # One-token decode: no seq dim to shard; shard the KV cache length
        # (flash-decoding).  Batch-1 long-context additionally folds the
        # data axis into the cache-seq shard (batch can't use it).  Heads
        # are replicated (grouped-q GQA math; head flops are negligible
        # against the cache traffic).
        base["act_seq"] = None
        base["act_cache_seq"] = ("data", "model")
        base["act_kv_seq"] = None
        base["act_heads"] = None
        base["act_kv_heads"] = None
    return base


def input_sharding(mesh: Mesh, rules: AxisRules, logical_axes, shape):
    return NamedSharding(mesh, logical_to_pspec(logical_axes, rules, mesh,
                                                shape))
