from repro.sharding.policy import (AxisRules, constrain, logical_to_pspec,
                                   make_rules, params_pspecs, use_rules)

__all__ = ["AxisRules", "constrain", "logical_to_pspec", "make_rules",
           "params_pspecs", "use_rules"]
