"""End-to-end int8 serving (paper C5 → serving tier).

The contract under test: a single ``PrecisionPolicy`` threaded from
params (QTensor) through the quant-aware matmul entry point
(``ops.quant_matmul``) into the Int8KV decode cache, with the
``fake_quant`` compute mode as the bit-faithful float oracle — int8
serving must be token-exact against it, and the int8 cache must buy a
≥2× KV-cache HBM reduction over the float32 baseline.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import quantize as qz
from repro.kernels import ops, ref
from repro.models import api
from repro.models.params import init_params
from repro.models.transformer import grow_cache
from repro.serve.kvcache import alloc_decode_cache, decode_cache_nbytes
from repro.serve.server import ContinuousBatchServer, StaticBatchServer

ARCH = "internlm2-1.8b"


@pytest.fixture(scope="module")
def setup():
    # f32 activations: the paper's C5 comparison baseline, and exact
    # fake-quant equivalence without bf16 double-rounding noise.
    cfg = dataclasses.replace(configs.get_smoke(ARCH), dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


# ---------------------------------------------------------------------------
# Policy / quantization units
# ---------------------------------------------------------------------------
def test_policy_for():
    assert qz.policy_for("float") is qz.FLOAT
    assert qz.policy_for("int8") is qz.INT8
    assert qz.policy_for(qz.INT8) is qz.INT8
    assert qz.INT8.kv_cache == "int8" and qz.INT8.weights == "int8"
    assert qz.INT8_FAKEQUANT.compute == "fake_quant"
    with pytest.raises(ValueError):
        qz.policy_for("fp4")
    with pytest.raises(AssertionError):
        qz.PrecisionPolicy(weights="int4")


def test_quant_dynamic_roundtrip():
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(6, 32) * 3, jnp.float32)
    q, s = qz.quant_dynamic(x)
    assert q.dtype == jnp.int8 and s.shape == (6,)
    err = np.abs(np.asarray(q, np.float32) * np.asarray(s)[:, None]
                 - np.asarray(x))
    # symmetric per-row quant: error bounded by half a step per element
    assert np.all(err <= np.asarray(s)[:, None] * 0.5 + 1e-7)
    # fake_quant is exactly the dequantized ints
    np.testing.assert_array_equal(
        np.asarray(qz.fake_quant_dynamic(x)),
        np.asarray(q, np.float32) * np.asarray(s)[:, None])


def test_quantize_model_params_scopes(setup):
    cfg, params = setup
    qp = qz.quantize_model_params(params, qz.INT8)
    assert isinstance(qp["blocks"]["attn"]["wq"], qz.QTensor)
    assert isinstance(qp["blocks"]["mlp"]["w_down"], qz.QTensor)
    # stacked layers keep per-layer per-channel scales
    L = cfg.n_layers
    assert qp["blocks"]["attn"]["wq"].scale.shape[0] == L
    # outside QUANT_SCOPES: float passthrough
    assert not isinstance(qp["embed"], qz.QTensor)
    assert not isinstance(qp["blocks"]["attn_norm"], qz.QTensor)
    # float policy is the identity
    assert qz.quantize_model_params(params, qz.FLOAT) is params


def test_quantize_model_params_moe_banks_stay_float():
    cfg = configs.get_smoke("dbrx-132b")
    params = init_params(cfg, jax.random.key(1))
    qp = qz.quantize_model_params(params, qz.INT8)
    assert isinstance(qp["blocks"]["attn"]["wq"], qz.QTensor)
    assert not isinstance(qp["blocks"]["moe"]["w_gate"], qz.QTensor)


def test_quant_matmul_paths():
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(5, 48), jnp.float32)
    w = jnp.asarray(rng.randn(48, 24) * 0.1, jnp.float32)
    # float path: identical to the pre-refactor matmul
    np.testing.assert_array_equal(np.asarray(ops.quant_matmul(x, w)),
                                  np.asarray(x @ w))
    qw = qz._leaf_qtensor(w)
    out_native = ops.quant_matmul(x, qw, policy=qz.INT8)
    out_fake = ops.quant_matmul(x, qw, policy=qz.INT8_FAKEQUANT)
    # the fake float simulation accumulates integer-valued f32 then
    # scales — same order as the int8 kernel, so it is BIT-identical
    # while dot products stay in f32's exact-integer range (K=48 here)
    np.testing.assert_array_equal(np.asarray(out_native),
                                  np.asarray(out_fake))
    # and both approximate the float matmul at int8 fidelity
    np.testing.assert_allclose(np.asarray(out_native), np.asarray(x @ w),
                               rtol=0.2, atol=0.05)


def test_quant_matmul_calibrated_amax():
    rng = np.random.RandomState(2)
    x = jnp.asarray(rng.randn(4, 32), jnp.float32)
    w = jnp.asarray(rng.randn(32, 16) * 0.1, jnp.float32)
    amax = qz.calibrate_amax([x, 2 * x, x])      # running max = 2*amax(x)
    qw = qz._leaf_qtensor(w)._replace(amax=jnp.float32(amax))
    pol = dataclasses.replace(qz.INT8, activations="calibrated")
    out = ops.quant_matmul(x, qw, policy=pol)
    xq, xs = qz.quant_dynamic(x, amax)
    expect = ref.int8_matmul_ref(xq, qw.q, xs, qw.scale)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-6)


def test_amax_observer_and_attach():
    obs = qz.AmaxObserver()
    obs.update(jnp.asarray([1.0, -3.0]))
    obs.update(jnp.asarray([2.0]))
    assert obs.amax == 3.0
    ema = qz.AmaxObserver(momentum=0.5)
    ema.update(jnp.asarray([4.0]))
    ema.update(jnp.asarray([0.0]))
    assert ema.amax == pytest.approx(2.0)

    w = jnp.ones((8, 4), jnp.float32)
    qp = {"attn": {"wq": qz._leaf_qtensor(w)}, "norm": jnp.ones((4,))}
    out = qz.attach_act_amax(qp, {"wq": 3.0})
    assert float(out["attn"]["wq"].amax) == 3.0
    assert out["attn"]["wq"].q is qp["attn"]["wq"].q
    # stacked leaves get a per-layer amax so lax.scan can slice it
    ws = jnp.ones((5, 8, 4), jnp.float32)
    out = qz.attach_act_amax({"mlp": {"w_up": qz._leaf_qtensor(ws)}},
                             {"w_up": 2.0})
    assert out["mlp"]["w_up"].amax.shape == (5,)


def test_calibrated_forward_on_stacked_model(setup):
    """Calibrated activation ranges must survive the scanned (stacked)
    param layout end-to-end: attach_act_amax broadcasts per-layer amax
    that lax.scan slices alongside the QTensor pair."""
    cfg, params = setup
    qparams = qz.quantize_model_params(params, qz.INT8)
    qparams = qz.attach_act_amax(
        qparams, {"wq": 4.0, "wk": 4.0, "wv": 4.0, "wo": 4.0,
                  "w_gate": 4.0, "w_up": 4.0, "w_down": 8.0})
    pol = dataclasses.replace(qz.INT8, activations="calibrated")
    fns = api.model_fns(cfg)
    toks = jnp.asarray(np.arange(8, dtype=np.int32)[None, :])
    logits, cache = fns.forward_prefill(cfg, qparams, {"tokens": toks}, pol)
    assert np.isfinite(np.asarray(logits)).all()
    cache = grow_cache(cfg, cache, 2)
    logits2, _ = fns.forward_decode(
        cfg, qparams, cache, jnp.asarray([3], jnp.int32),
        jnp.asarray([8], jnp.int32), policy=pol)
    assert np.isfinite(np.asarray(logits2)).all()


def test_kv_quant_roundtrip_and_policy_modes():
    rng = np.random.RandomState(3)
    k = jnp.asarray(rng.randn(2, 5, 3, 16), jnp.float32)
    kv = qz.quant_kv(k)
    assert kv.q.shape == k.shape and kv.scale.shape == (2, 5, 3)
    err = np.abs(np.asarray(qz.dequant_kv(kv)) - np.asarray(k))
    assert np.all(err <= np.asarray(kv.scale)[..., None] * 0.5 + 1e-7)
    # policy modes: passthrough / native pair / fake float
    assert qz.maybe_quant_kv(qz.FLOAT, k) is k
    native = qz.maybe_quant_kv(qz.INT8, k)
    assert isinstance(native, qz.Int8KV)
    fake = qz.maybe_quant_kv(qz.INT8_FAKEQUANT, k)
    # the fake float cache holds exactly the dequantized int8 values
    np.testing.assert_array_equal(np.asarray(qz.dequant_kv(native)),
                                  np.asarray(fake))


# ---------------------------------------------------------------------------
# Serving: token-exact int8 vs fake-quant float reference (acceptance)
# ---------------------------------------------------------------------------
def _fake_quant_reference(cfg, qparams, prompt, max_new):
    """Greedy contiguous decode of the float fake-quant simulation — the
    oracle the native int8 path must reproduce token-exactly."""
    pol = qz.INT8_FAKEQUANT
    fns = api.model_fns(cfg)
    logits, cache = fns.forward_prefill(
        cfg, qparams, {"tokens": jnp.asarray(prompt[None, :])}, pol)
    cache = grow_cache(cfg, cache, max_new + 1)
    out = [int(jnp.argmax(logits, -1)[0])]
    pos = len(prompt)
    for _ in range(max_new - 1):
        logits, cache = fns.forward_decode(
            cfg, qparams, cache, jnp.asarray([out[-1]], jnp.int32),
            jnp.asarray([pos], jnp.int32), policy=pol)
        out.append(int(jnp.argmax(logits, -1)[0]))
        pos += 1
    return out


def test_int8_serving_token_exact(setup):
    """Continuous int8 serving (chunked pad-free prefill, slot-recycled
    Int8KV cache, ref kernel path) == fake-quant float reference."""
    cfg, params = setup
    rng = np.random.RandomState(4)
    lens = [3, 11, 7]
    budgets = [5, 4, 6]
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    srv = ContinuousBatchServer(cfg, params, slots=2, max_prompt=16,
                                prefill_chunk=4, max_new_tokens=8,
                                precision="int8")
    reqs = srv.submit(prompts, max_new_tokens=budgets)
    m = srv.run()
    assert m["precision"] == "int8"
    qparams = qz.quantize_model_params(params, qz.INT8)
    for r, p, b in zip(reqs, prompts, budgets):
        assert r.tokens == _fake_quant_reference(cfg, qparams, p, b), \
            f"rid {r.rid}: int8 serving diverged from fake-quant reference"
    # quantization is real at the numeric level: int8 logits differ from
    # float logits (greedy tokens may still coincide on a smoke model)
    fns = api.model_fns(cfg)
    t0 = jnp.asarray(prompts[1][None, :])
    lf, _ = fns.forward_prefill(cfg, params, {"tokens": t0})
    lq, _ = fns.forward_prefill(cfg, qparams, {"tokens": t0}, qz.INT8)
    assert not np.allclose(np.asarray(lf), np.asarray(lq), atol=1e-6), \
        "int8 path produced float-identical logits — quantization inactive"


def test_static_and_continuous_agree_int8(setup):
    """Scheduling still never changes tokens — now at int8."""
    cfg, params = setup
    rng = np.random.RandomState(5)
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (4, 9, 6)]
    budgets = [3, 5, 2]
    stat = StaticBatchServer(cfg, params, batch_size=2, max_prompt=16,
                             max_new_tokens=8, precision="int8")
    sreqs = stat.submit(prompts, max_new_tokens=budgets)
    ms = stat.run()
    cont = ContinuousBatchServer(cfg, params, slots=2, max_prompt=16,
                                 max_new_tokens=8, precision="int8")
    creqs = cont.submit(prompts, max_new_tokens=budgets)
    cont.run()
    assert [r.tokens for r in sreqs] == [r.tokens for r in creqs]
    assert ms["precision"] == "int8"


# ---------------------------------------------------------------------------
# KV-cache HBM: the Table-4 story on the serving tier
# ---------------------------------------------------------------------------
def test_kv_cache_hbm_reduction(setup):
    cfg, _ = setup
    f_cache = alloc_decode_cache(cfg, slots=4, capacity=40)
    q_cache = alloc_decode_cache(cfg, slots=4, capacity=40, policy=qz.INT8)
    f_bytes = decode_cache_nbytes(f_cache)
    q_bytes = decode_cache_nbytes(q_cache)
    assert f_bytes / q_bytes >= 2.0, (f_bytes, q_bytes)
    # structure: Int8KV pairs with int8 values and f32 per-entry scales
    assert isinstance(q_cache["k"], qz.Int8KV)
    assert q_cache["k"].q.dtype == jnp.int8
    assert q_cache["k"].scale.dtype == jnp.float32
    assert q_cache["k"].scale.shape == q_cache["k"].q.shape[:-1]


def test_kv_cache_bytes_arithmetic():
    from repro.serve.kvcache import kv_cache_bytes
    cfg = configs.get("internlm2-1.8b")
    fb = kv_cache_bytes(cfg, 8, 4096, 4)
    qb = kv_cache_bytes(cfg, 8, 4096, 4, precision="int8")
    hd = cfg.resolved_head_dim
    assert fb / qb == pytest.approx(4 * hd / (hd + 4))
    # ssm state is float under every precision
    ssm = configs.get("falcon-mamba-7b")
    assert kv_cache_bytes(ssm, 8, 4096, 4) == \
        kv_cache_bytes(ssm, 8, 4096, 4, precision="int8")


def test_compile_serve_decode_int8_reports_hbm_delta(setup):
    from repro.core.eon_compiler import compile_serve_decode
    cfg, params = setup
    qparams = qz.quantize_model_params(params, qz.INT8)
    art = compile_serve_decode(cfg, qparams, slots=2, capacity=12,
                               policy=qz.INT8)
    assert art.name.endswith("-int8")
    mem = art.memory
    assert mem["kv_cache_bytes_float"] / mem["kv_cache_bytes"] >= 2.0
    # the serialized executable stays runnable; decode signature is
    # (params, cache, token, position, kv_len) — index == position under
    # pad-free admission, so there is no separate write_idx operand
    fn = art.rehydrate()
    cache = alloc_decode_cache(cfg, 2, 12, qz.INT8)
    tok = jnp.zeros((2,), jnp.int32)
    ntok, _, _ = fn(qparams, cache, tok, tok, tok)
    assert ntok.shape == (2,)
