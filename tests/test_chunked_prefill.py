"""Chunked pad-free prefill: kernel parity, model-level chunk == one-shot,
serving token-exactness across architecture families, and scheduler
fairness.

The contracts under test (docs/scheduling.md):

* ``ops.chunk_attention`` (interpret-mode Pallas vs jnp ref): grouped-q
  GQA, position masking, per-slot ``kv_len`` bounding, pad query rows
  (ragged final chunk) returning exact zeros, in-tile Int8KV dequant;
  ``decode_attention`` is its C == 1 special case.
* ``forward_prefill_chunk`` called ceil(S / C) times reproduces the
  one-shot ``forward_prefill`` logits and cache for every family —
  uniform attention, sliding-window ring, SSM, hybrid, and enc-dec —
  including ragged final chunks (the SSM recurrence sees no pad input,
  the previously-caveated scenario, now exact).
* Chunked continuous serving is token-exact vs the unpadded one-shot
  reference for chunk sizes {1, C, S, > S} × {float, int8}.
* A slot mid-prefill never emits tokens, and a long prefill cannot
  starve active decode slots beyond the per-step token budget.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core import quantize as qz
from repro.kernels import ops
from repro.models import api
from repro.models.params import init_params
from repro.models.transformer import grow_cache
from repro.serve.kvcache import alloc_decode_cache
from repro.serve.scheduler import SlotScheduler
from repro.serve.server import ContinuousBatchServer


@functools.lru_cache(maxsize=None)
def _setup(arch):
    cfg = dataclasses.replace(configs.get_smoke(arch), dtype="float32")
    return cfg, init_params(cfg, jax.random.key(0))


# ---------------------------------------------------------------------------
# Kernel parity: interpret-mode Pallas vs jnp ref
# ---------------------------------------------------------------------------
def _chunk_case(rng, b, c, s, hq, hkv, d, fills, reals):
    """Row i holds ``fills[i]`` live entries at positions 0..fills−1; the
    chunk's ``reals[i]`` real queries sit at the tail positions (pad
    query rows beyond get position −1)."""
    q = jnp.asarray(rng.randn(b, c, hq, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, hkv, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, hkv, d), jnp.float32)
    pos = np.full((b, s), -1, np.int32)
    qpos = np.full((b, c), -1, np.int32)
    for i, (n, r) in enumerate(zip(fills, reals)):
        pos[i, :n] = np.arange(n)
        qpos[i, :r] = np.arange(n - r, n)
    return (q, k, v, jnp.asarray(qpos), jnp.asarray(pos),
            jnp.asarray(fills, jnp.int32))


@pytest.mark.parametrize("precision", ["float", "int8"])
@pytest.mark.parametrize("window", [0, 4])
@pytest.mark.parametrize("hkv", [4, 2, 1])     # GQA ratios 1, 2, 4
def test_chunk_attention_parity(hkv, window, precision):
    """interpret == ref across GQA ratios, windows, precisions, ragged
    per-slot kv_len, and pad query rows (which are exactly zero)."""
    rng = np.random.RandomState(0)
    b, c, s, hq, d = 3, 5, 24, 4, 16
    q, k, v, qpos, pos, kvl = _chunk_case(
        rng, b, c, s, hq, hkv, d, fills=[7, 5, 24], reals=[5, 3, 5])
    if precision == "int8":
        k, v = qz.quant_kv(k), qz.quant_kv(v)
    out_ref = ops.chunk_attention(q, k, v, qpos, pos, window=window,
                                  kv_len=kvl, force="ref")
    out_int = ops.chunk_attention(q, k, v, qpos, pos, window=window,
                                  kv_len=kvl, force="interpret")
    np.testing.assert_allclose(np.asarray(out_int), np.asarray(out_ref),
                               atol=1e-5)
    # pad query rows (ragged final chunk): exactly zero on both paths
    assert np.all(np.asarray(out_ref)[1, 3:] == 0)
    assert np.all(np.asarray(out_int)[1, 3:] == 0)


@pytest.mark.parametrize("force", ["ref", "interpret"])
def test_chunk_attention_c1_matches_decode(force):
    """C == 1 chunk attention is decode attention (same masking, same
    grouped-q math) — the degenerate chunk size the spec pins."""
    rng = np.random.RandomState(1)
    b, s, hq, hkv, d = 4, 24, 4, 2, 16
    q = jnp.asarray(rng.randn(b, 1, hq, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, hkv, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, hkv, d), jnp.float32)
    pos = np.full((b, s), -1, np.int32)
    fills = [3, 9, 16, 24]
    for i, n in enumerate(fills):
        pos[i, :n] = np.arange(n)
    qp = jnp.asarray([n - 1 for n in fills], jnp.int32)
    kvl = jnp.asarray(fills, jnp.int32)
    chunk = ops.chunk_attention(q, k, v, qp[:, None], jnp.asarray(pos),
                                kv_len=kvl, force=force)
    dec = ops.decode_attention(q, k, v, qp, jnp.asarray(pos),
                               kv_len=kvl, force=force)
    np.testing.assert_allclose(np.asarray(chunk), np.asarray(dec),
                               atol=1e-6)


def test_chunk_attention_kv_len_blocks_skipped():
    """Poison the cache beyond kv_len with attendable-looking entries:
    the chunk kernel must not read them (bound is a skip, not a mask)."""
    rng = np.random.RandomState(2)
    b, c, s, hq, hkv, d = 2, 3, 32, 4, 2, 16
    q, k, v, qpos, pos, kvl = _chunk_case(
        rng, b, c, s, hq, hkv, d, fills=[6, 9], reals=[3, 3])
    clean = [ops.chunk_attention(q, k, v, qpos, pos, kv_len=kvl, force=f)
             for f in ("ref", "interpret")]
    pos_bad = np.asarray(pos).copy()
    k_bad, v_bad = np.asarray(k).copy(), np.asarray(v).copy()
    for i, n in enumerate(np.asarray(kvl)):
        pos_bad[i, n:] = 0
        k_bad[i, n:] = 100.0
        v_bad[i, n:] = 100.0
    for f, want in zip(("ref", "interpret"), clean):
        got = ops.chunk_attention(q, jnp.asarray(k_bad), jnp.asarray(v_bad),
                                  qpos, jnp.asarray(pos_bad), kv_len=kvl,
                                  force=f)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f)


# ---------------------------------------------------------------------------
# SSM ragged-chunk masking: pad steps are exact state no-ops
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("arch,variant", [("falcon-mamba-7b", "mamba1"),
                                          ("zamba2-2.7b", "mamba2")])
def test_mamba_mask_fill_exact_state(arch, variant):
    """A masked ragged tail leaves (conv, h) where the last real token
    put them — compare against running the truncated real prefix."""
    from repro.models import ssm as ssm_mod
    cfg, params = _setup(arch)
    if variant == "mamba1":
        p = jax.tree.map(lambda x: x[0], params["blocks"])["mamba"]
        fn = ssm_mod.mamba1_layer
    else:
        p = jax.tree.map(lambda x: x[0], params["groups"])
        p = jax.tree.map(lambda x: x[0], p)["mamba"]
        fn = ssm_mod.mamba2_layer
    rng = np.random.RandomState(3)
    s, real = 8, 5
    x = jnp.asarray(rng.randn(1, s, cfg.d_model) * 0.1, jnp.float32)
    mask = jnp.asarray(np.arange(s)[None, :] < real)
    fill = jnp.asarray([real], jnp.int32)
    _, st_masked = fn(p, x, cfg, mask=mask, fill=fill)
    _, st_trunc = fn(p, x[:, :real], cfg)
    np.testing.assert_allclose(np.asarray(st_masked.conv),
                               np.asarray(st_trunc.conv), atol=1e-6)
    np.testing.assert_allclose(np.asarray(st_masked.h),
                               np.asarray(st_trunc.h), atol=1e-5)


# ---------------------------------------------------------------------------
# Model level: chunked prefill == one-shot prefill
# ---------------------------------------------------------------------------
def _chunked_prefill(cfg, params, prompt, chunk, capacity, policy=None):
    """Drive forward_prefill_chunk over a whole prompt; returns the last
    real row's logits and the resulting cache."""
    fns = api.model_fns(cfg)
    cache = alloc_decode_cache(cfg, 1, capacity, policy)
    s, p, last = len(prompt), 0, None
    while p < s:
        r = min(chunk, s - p)
        toks = np.zeros((1, chunk), np.int32)
        poss = np.full((1, chunk), -1, np.int32)
        toks[0, :r] = prompt[p:p + r]
        poss[0, :r] = np.arange(p, p + r, dtype=np.int32)
        logits, cache = fns.forward_prefill_chunk(
            cfg, params, cache, jnp.asarray(toks), jnp.asarray(poss),
            policy=policy, kv_len=jnp.asarray([p + chunk], jnp.int32))
        last = np.asarray(logits)[0, r - 1]
        p += r
    return last, cache


# the uniform arch sweeps every chunk size {1, C, S, > S}; the slower
# trunks pin the two interesting shapes (ragged tail, single ragged
# chunk) — the serving tests below re-cover chunk == 1 end to end.
@pytest.mark.parametrize("arch,chunk", [
    ("internlm2-1.8b", 1), ("internlm2-1.8b", 4),
    ("internlm2-1.8b", 11), ("internlm2-1.8b", 16),
    ("gemma3-4b", 4), ("gemma3-4b", 16),
    ("falcon-mamba-7b", 4), ("falcon-mamba-7b", 16),
    ("zamba2-2.7b", 4), ("zamba2-2.7b", 16),
])
def test_chunked_prefill_matches_oneshot(arch, chunk):
    """ceil(S/C) chunk steps == one full prefill: same greedy token and
    logits to float tolerance, for every trunk family and chunk size
    (11 == S exercises the exact-fit path, 16 > S the single ragged
    chunk, 4 the ragged-tail path the SSM masking must get right)."""
    cfg, params = _setup(arch)
    rng = np.random.RandomState(0)
    prompt = rng.randint(0, cfg.vocab_size, 11).astype(np.int32)
    ref_logits, _ = api.model_fns(cfg).forward_prefill(
        cfg, params, {"tokens": jnp.asarray(prompt[None, :])})
    ref = np.asarray(ref_logits)[0]
    last, _ = _chunked_prefill(cfg, params, prompt, chunk, capacity=24)
    np.testing.assert_allclose(last, ref, atol=2e-4)
    assert int(last.argmax()) == int(ref.argmax())


def test_chunked_prefill_encdec_matches_oneshot():
    """The enc-dec decoder prefills in chunks too: encoder runs once
    (init_chunk_cache), decoder chunks attend self prefix + cross KV."""
    from repro.models import encdec
    cfg, params = _setup("seamless-m4t-large-v2")
    fns = api.model_fns(cfg)
    rng = np.random.RandomState(1)
    s, chunk, cap = 10, 4, 16
    enc = jnp.asarray(rng.randn(1, s // cfg.enc_seq_divisor, cfg.d_model)
                      * 0.1, jnp.float32)
    prompt = rng.randint(0, cfg.vocab_size, s).astype(np.int32)
    ref_logits, _ = fns.forward_prefill(
        cfg, params, {"tokens": jnp.asarray(prompt[None, :]),
                      "enc_embeddings": enc})
    cache = encdec.init_chunk_cache(cfg, params, enc, cap)
    p, last = 0, None
    while p < s:
        r = min(chunk, s - p)
        toks = np.zeros((1, chunk), np.int32)
        poss = np.full((1, chunk), -1, np.int32)
        toks[0, :r] = prompt[p:p + r]
        poss[0, :r] = np.arange(p, p + r, dtype=np.int32)
        logits, cache = fns.forward_prefill_chunk(
            cfg, params, cache, jnp.asarray(toks), jnp.asarray(poss),
            kv_len=jnp.asarray([p + chunk], jnp.int32))
        last = np.asarray(logits)[0, r - 1]
        p += r
    ref = np.asarray(ref_logits)[0]
    np.testing.assert_allclose(last, ref, atol=2e-4)
    assert int(last.argmax()) == int(ref.argmax())


# ---------------------------------------------------------------------------
# Serving: token-exact across chunk sizes × precisions × families
# ---------------------------------------------------------------------------
def _reference_decode(cfg, params, prompt, max_new):
    fns = api.model_fns(cfg)
    logits, cache = fns.forward_prefill(
        cfg, params, {"tokens": jnp.asarray(prompt[None, :])})
    cache = grow_cache(cfg, cache, max_new + 1)
    out = [int(jnp.argmax(logits, -1)[0])]
    pos = len(prompt)
    for _ in range(max_new - 1):
        logits, cache = fns.forward_decode(
            cfg, params, cache, jnp.asarray([out[-1]], jnp.int32),
            jnp.asarray([pos], jnp.int32))
        out.append(int(jnp.argmax(logits, -1)[0]))
        pos += 1
    return out


_LENS, _BUDGETS = (4, 12, 7), (4, 3, 5)


def _workload(cfg, seed=5):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
            for n in _LENS]


@functools.lru_cache(maxsize=None)
def _references(arch, seed=5):
    """One-shot unpadded reference streams, shared across the chunk-size
    parametrization (each serving run compares against the same oracle)."""
    cfg, params = _setup(arch)
    return [_reference_decode(cfg, params, p, b)
            for p, b in zip(_workload(cfg, seed), _BUDGETS)]


@pytest.mark.parametrize("chunk", [1, 4, 16])   # 1; C == S of prompt 0
#                                               # (and divides 12); > all S
@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma3-4b",
                                  "falcon-mamba-7b", "zamba2-2.7b"])
def test_chunked_serving_token_exact_float(arch, chunk):
    """ACCEPTANCE: chunked continuous serving — prefill interleaved with
    decode, no pad rows — is token-exact vs the one-shot unpadded
    reference on attention, ring, SSM, and hybrid architectures.  The
    SSM/hybrid rows are the previously-caveated scenario, now exact."""
    cfg, params = _setup(arch)
    prompts = _workload(cfg)
    srv = ContinuousBatchServer(cfg, params, slots=2, max_prompt=16,
                                prefill_chunk=chunk, max_new_tokens=8)
    reqs = srv.submit(prompts, max_new_tokens=list(_BUDGETS))
    srv.run()
    for r, ref in zip(reqs, _references(arch)):
        assert r.tokens == ref, \
            f"{arch} chunk={chunk} rid {r.rid} diverged"


@pytest.mark.parametrize("chunk", [1, 4, 16])
@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma3-4b",
                                  "zamba2-2.7b"])
def test_chunked_serving_token_exact_int8(arch, chunk):
    """Native int8 chunked serving == the fake-quant float oracle
    through the same chunk schedule (the oracle's float cache holds
    exactly the dequantized int8 values at every chunk write)."""
    cfg, params = _setup(arch)
    prompts = _workload(cfg, seed=6)
    kw = dict(slots=2, max_prompt=16, prefill_chunk=chunk,
              max_new_tokens=8)
    srv = ContinuousBatchServer(cfg, params, precision="int8", **kw)
    reqs = srv.submit(prompts, max_new_tokens=list(_BUDGETS))
    srv.run()
    fq = ContinuousBatchServer(cfg, params, precision="int8_fakequant",
                               **kw)
    freqs = fq.submit(prompts, max_new_tokens=list(_BUDGETS))
    fq.run()
    assert [r.tokens for r in reqs] == [r.tokens for r in freqs], \
        f"{arch} chunk={chunk}: int8 diverged from fake-quant oracle"


# ---------------------------------------------------------------------------
# Scheduler: no mid-prefill emission, no decode starvation
# ---------------------------------------------------------------------------
def test_prefilling_slot_is_not_active():
    """A slot mid-prefill is never in the decode set (so it can never
    emit a token), and flips active only when its prompt is exhausted."""
    s = SlotScheduler(1)
    slot = s.slots[0]
    slot.occupy(0, np.arange(9, dtype=np.int32), 4)
    assert s.prefilling_slots() == [slot]
    assert s.active_slots() == []
    slot.chunk_pos = 9
    slot.begin_decode()
    assert s.prefilling_slots() == []
    assert s.active_slots() == [slot]


def test_long_prefill_does_not_starve_decode():
    """With a one-chunk-per-step budget, a 20-token prompt admitted next
    to an active slot must not delay that slot's tokens: the short
    request finishes after exactly its max_new − 1 decode steps, the
    long one emits nothing until its prefill completes, and both are
    token-exact under the interleaving."""
    cfg, params = _setup("internlm2-1.8b")
    rng = np.random.RandomState(7)
    short = rng.randint(0, cfg.vocab_size, 4).astype(np.int32)
    long = rng.randint(0, cfg.vocab_size, 20).astype(np.int32)
    srv = ContinuousBatchServer(cfg, params, slots=2, max_prompt=24,
                                prefill_chunk=4, prefill_token_budget=4,
                                max_new_tokens=10)
    ra, rb = srv.submit([short, long], max_new_tokens=[10, 6])
    srv.run()
    # short request decoded every step: 1 prefill token + 9 decode steps
    assert ra.finished_step == 9, \
        f"short request starved behind the long prefill ({ra.finished_step})"
    # the long prompt (5 chunks, 1 chunk/step) emits its first token
    # only after the short slot has produced several decode tokens
    assert rb.first_token_at > ra.first_token_at
    assert len(rb.tokens) == 6
    # interleaving never corrupts either stream
    assert ra.tokens == _reference_decode(cfg, params, short, 10)
    assert rb.tokens == _reference_decode(cfg, params, long, 6)
