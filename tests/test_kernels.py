"""Per-kernel correctness: interpret-mode Pallas vs pure-jnp oracle,
swept over shapes and dtypes."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention as fa_kernel
from repro.kernels.int8_matmul import int8_matmul as im_kernel
from repro.kernels.mamba_scan import mamba_scan as ms_kernel
from repro.kernels.mel_frontend import mel_frontend as mf_kernel


# ---------------------------------------------------------------------------
# int8 matmul
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (128, 256, 128, 128, 128, 128),
    (256, 512, 384, 128, 128, 256),
    (64, 128, 64, 64, 64, 64),
    (128, 256, 128, 128, 128, 64),   # multi-step K accumulation
])
def test_int8_matmul_shapes(m, k, n, bm, bn, bk):
    rng = np.random.RandomState(0)
    xq = jnp.asarray(rng.randint(-127, 128, (m, k)), jnp.int8)
    wq = jnp.asarray(rng.randint(-127, 128, (k, n)), jnp.int8)
    xs = jnp.asarray(rng.uniform(1e-3, 2e-2, (m,)), jnp.float32)
    ws = jnp.asarray(rng.uniform(1e-3, 2e-2, (n,)), jnp.float32)
    out = im_kernel(xq, wq, xs, ws, bm=bm, bn=bn, bk=bk, interpret=True)
    expect = ref.int8_matmul_ref(xq, wq, xs, ws)
    np.testing.assert_allclose(out, expect, rtol=1e-6)


@pytest.mark.parametrize("m,k,n", [
    (3, 70, 5),        # everything ragged, smaller than one tile
    (100, 200, 96),    # M/K ragged vs 64-blocks
    (130, 300, 190),   # every dim crosses a tile boundary mid-block
    (1, 64, 1),        # decode-shaped: single row/col
    (257, 129, 65),    # one past a tile edge in every dim
])
def test_int8_matmul_ragged_parity(m, k, n):
    """Pallas interpret == int32-exact ref on non-multiple-of-block
    shapes: the kernel zero-pads up to the tile grid (zero int8 entries
    add nothing to the int32 dot) and slices the output back."""
    rng = np.random.RandomState(11)
    xq = jnp.asarray(rng.randint(-127, 128, (m, k)), jnp.int8)
    wq = jnp.asarray(rng.randint(-127, 128, (k, n)), jnp.int8)
    xs = jnp.asarray(rng.uniform(1e-3, 2e-2, (m,)), jnp.float32)
    ws = jnp.asarray(rng.uniform(1e-3, 2e-2, (n,)), jnp.float32)
    out = im_kernel(xq, wq, xs, ws, bm=64, bn=64, bk=64, interpret=True)
    assert out.shape == (m, n)
    np.testing.assert_allclose(out, ref.int8_matmul_ref(xq, wq, xs, ws),
                               rtol=1e-6)


def test_kernel_path_flag_pins_dispatch():
    """repro.flags kernel_path pins every ops dispatch (the CI lever for
    running the suite through Pallas interpret mode); per-call force
    still wins."""
    from repro import flags
    old = flags.get("kernel_path")
    try:
        flags.set_flags(kernel_path="interpret")
        assert ops.resolve_path() == "interpret"
        assert ops.resolve_path("ref") == "ref"   # per-call force wins
        rng = np.random.RandomState(12)
        xq = jnp.asarray(rng.randint(-127, 128, (16, 96)), jnp.int8)
        wq = jnp.asarray(rng.randint(-127, 128, (96, 40)), jnp.int8)
        xs = jnp.asarray(rng.uniform(1e-3, 2e-2, (16,)), jnp.float32)
        ws = jnp.asarray(rng.uniform(1e-3, 2e-2, (40,)), jnp.float32)
        out = ops.int8_matmul(xq, wq, xs, ws)     # runs interpret-mode pallas
        np.testing.assert_allclose(out, ref.int8_matmul_ref(xq, wq, xs, ws),
                                   rtol=1e-6)
        with pytest.raises(ValueError):
            flags.set_flags(kernel_path="cuda")
    finally:
        flags.set_flags(kernel_path=old)
    assert ops.resolve_path("pallas") == "pallas"


def test_kernel_path_env_seed(monkeypatch):
    """$REPRO_KERNEL_PATH seeds the flag at import (the suite-wide CI
    switch)."""
    import importlib

    from repro import flags
    old = flags.get("kernel_path")
    try:
        monkeypatch.setenv("REPRO_KERNEL_PATH", "interpret")
        importlib.reload(flags)
        assert flags.get("kernel_path") == "interpret"
        monkeypatch.setenv("REPRO_KERNEL_PATH", "metal")
        with pytest.raises(ValueError):
            importlib.reload(flags)
    finally:
        monkeypatch.delenv("REPRO_KERNEL_PATH", raising=False)
        importlib.reload(flags)
        flags.set_flags(kernel_path=old)


@settings(max_examples=10, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4), st.integers(1, 4),
       st.integers(0, 2 ** 31 - 1))
def test_int8_matmul_property(mi, ki, ni, seed):
    """Property: kernel == int32-exact reference for any tile multiple."""
    m, k, n = mi * 64, ki * 64, ni * 64
    rng = np.random.RandomState(seed % (2 ** 31))
    xq = jnp.asarray(rng.randint(-127, 128, (m, k)), jnp.int8)
    wq = jnp.asarray(rng.randint(-127, 128, (k, n)), jnp.int8)
    xs = jnp.asarray(rng.uniform(1e-3, 2e-2, (m,)), jnp.float32)
    ws = jnp.asarray(rng.uniform(1e-3, 2e-2, (n,)), jnp.float32)
    out = im_kernel(xq, wq, xs, ws, bm=64, bn=64, bk=64, interpret=True)
    np.testing.assert_allclose(out, ref.int8_matmul_ref(xq, wq, xs, ws),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("s,d,bq,bk,causal,window", [
    (256, 64, 128, 128, True, 0),
    (256, 64, 64, 128, True, 64),
    (512, 128, 128, 256, True, 0),
    (256, 64, 128, 128, False, 0),
])
def test_flash_attention(s, d, bq, bk, causal, window, dtype):
    b, h = 2, 2
    keys = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(keys[0], (b, s, h, d), jnp.float32).astype(dtype)
    k = jax.random.normal(keys[1], (b, s, h, d), jnp.float32).astype(dtype)
    v = jax.random.normal(keys[2], (b, s, h, d), jnp.float32).astype(dtype)

    def fold(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, s, d)

    out = fa_kernel(fold(q), fold(k), fold(v), causal=causal, window=window,
                    block_q=bq, block_k=bk, interpret=True)
    out = out.reshape(b, h, s, d).transpose(0, 2, 1, 3)
    expect = ref.flash_attention_ref(q, k, v, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 1e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), atol=tol)


def test_flash_attention_gqa_dispatch():
    """ops wrapper expands GQA heads before the kernel."""
    b, s, hq, hkv, d = 1, 128, 4, 2, 32
    keys = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(keys[0], (b, s, hq, d))
    k = jax.random.normal(keys[1], (b, s, hkv, d))
    v = jax.random.normal(keys[2], (b, s, hkv, d))
    out = ops.flash_attention(q, k, v, force="interpret")
    expect = ref.flash_attention_ref(q, jnp.repeat(k, 2, 2),
                                     jnp.repeat(v, 2, 2))
    np.testing.assert_allclose(out, expect, atol=1e-5)


# ---------------------------------------------------------------------------
# mamba selective scan
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("s,d,n,bd,chunk", [
    (128, 64, 16, 64, 64),
    (256, 64, 16, 32, 128),
    (128, 128, 8, 64, 32),
])
def test_mamba_scan(s, d, n, bd, chunk):
    b = 2
    keys = jax.random.split(jax.random.key(2), 5)
    x = jax.random.normal(keys[0], (b, s, d)) * 0.5
    dt = jax.nn.softplus(jax.random.normal(keys[1], (b, s, d)) * 0.5)
    bm = jax.random.normal(keys[2], (b, s, n)) * 0.5
    cm = jax.random.normal(keys[3], (b, s, n)) * 0.5
    a = -jnp.exp(jax.random.normal(keys[4], (d, n)) * 0.3)
    y, h = ms_kernel(x, dt, bm, cm, a, block_d=bd, chunk=chunk,
                     interpret=True)
    yr, hr = ref.mamba_scan_ref(x, dt, bm, cm, a)
    np.testing.assert_allclose(y, yr, atol=2e-5)
    np.testing.assert_allclose(h, hr, atol=2e-5)


@settings(max_examples=6, deadline=None)
@given(st.integers(0, 2 ** 31 - 1))
def test_mamba_scan_state_decay_property(seed):
    """Property: with dt→0 the state stays ~h0=0 and y→0 (pure decay)."""
    rng = np.random.RandomState(seed % (2 ** 31))
    b, s, d, n = 1, 64, 32, 8
    x = jnp.asarray(rng.randn(b, s, d), jnp.float32)
    dt = jnp.full((b, s, d), 1e-6, jnp.float32)
    bm = jnp.asarray(rng.randn(b, s, n), jnp.float32)
    cm = jnp.asarray(rng.randn(b, s, n), jnp.float32)
    a = -jnp.ones((d, n), jnp.float32)
    y, h = ms_kernel(x, dt, bm, cm, a, block_d=32, chunk=32, interpret=True)
    assert float(jnp.max(jnp.abs(y))) < 1e-2
    assert float(jnp.max(jnp.abs(h))) < 1e-2


# ---------------------------------------------------------------------------
# mel frontend
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("f,l,nbins,nmels,bf", [
    (128, 256, 129, 40, 64),
    (256, 512, 257, 32, 128),
])
def test_mel_frontend(f, l, nbins, nmels, bf):
    rng = np.random.RandomState(3)
    frames = jnp.asarray(rng.randn(f, l), jnp.float32)
    window = jnp.hanning(l).astype(jnp.float32)
    kk = np.arange(nbins)[None, :] * np.arange(l)[:, None] * 2 * np.pi / l
    dc = jnp.asarray(np.cos(kk), jnp.float32)
    dsn = jnp.asarray(-np.sin(kk), jnp.float32)
    mel = jnp.asarray(rng.rand(nbins, nmels), jnp.float32)
    out = mf_kernel(frames, window, dc, dsn, mel, block_f=bf, interpret=True)
    expect = ref.mel_frontend_ref(frames[None], window, dc, dsn, mel)[0]
    np.testing.assert_allclose(out, expect, rtol=1e-4, atol=1e-5)


def test_mel_frontend_matches_numpy_fft():
    """DFT-as-matmul == numpy rfft power spectrum (the hardware-adaptation
    claim: the matmul formulation is exact, not an approximation)."""
    l, nbins = 256, 129
    rng = np.random.RandomState(4)
    frames = rng.randn(8, l).astype(np.float32)
    window = np.hanning(l).astype(np.float32)
    kk = np.arange(nbins)[None, :] * np.arange(l)[:, None] * 2 * np.pi / l
    dc, dsn = np.cos(kk), -np.sin(kk)
    xw = frames * window
    re = xw @ dc
    im = xw @ dsn
    power = re ** 2 + im ** 2
    fft_power = np.abs(np.fft.rfft(xw, axis=-1)) ** 2
    np.testing.assert_allclose(power, fft_power, rtol=1e-3, atol=1e-3)
