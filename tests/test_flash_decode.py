"""Flash-decode kernel: interpret-mode Pallas vs jnp ref, and the
serving tier running end-to-end through the kernel.

The contract under test (kernels/flash_decode.py):

* grouped-q GQA in-kernel (KV never repeated), any Hq/Hkv ratio;
* position-validity masking identical to the ref (−1 invalid,
  ``pos <= q_pos``, sliding window);
* per-slot ``kv_len`` bounding — blocks past the high-water mark are
  *skipped*, not just masked (verified by poisoning the tail);
* fused Int8KV dequant inside the tile — the decode path never
  materializes a float copy of the cache (verified by spying on
  ``dequant_kv``);
* a slot with no valid entries (kv_len == 0) returns exactly zeros.

Continuous serving with ``kernel_path="interpret"`` forced must stay
token-exact versus the same-path reference decode (float) and the
fake-quant float oracle (int8) — including the gemma3-style
local:global sliding-window ring architecture.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, flags
from repro.core import quantize as qz
from repro.kernels import ops
from repro.models import api
from repro.models.params import init_params
from repro.models.transformer import grow_cache
from repro.serve.server import ContinuousBatchServer


# ---------------------------------------------------------------------------
# Kernel parity: interpret-mode Pallas vs jnp ref
# ---------------------------------------------------------------------------
def _slot_case(rng, b, s, hq, hkv, d, kv_lens, pads):
    """Build a slot-cache decode case: row i holds ``kv_lens[i]`` entries
    (−1 positions beyond), the first ``pads[i]`` of them left-pad (−1)."""
    q = jnp.asarray(rng.randn(b, 1, hq, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, s, hkv, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, hkv, d), jnp.float32)
    pos = np.full((b, s), -1, np.int32)
    for i, (n, pad) in enumerate(zip(kv_lens, pads)):
        pos[i, pad:n] = np.arange(n - pad)
    q_pos = jnp.asarray(np.maximum(np.array(kv_lens) - np.array(pads) - 1, 0),
                        jnp.int32)
    return q, k, v, q_pos, jnp.asarray(pos), jnp.asarray(kv_lens, jnp.int32)


@pytest.mark.parametrize("precision", ["float", "int8"])
@pytest.mark.parametrize("window", [0, 4])
@pytest.mark.parametrize("hkv", [4, 2, 1])     # GQA ratios 1, 2, 4
def test_flash_decode_parity(hkv, window, precision):
    """interpret == ref across GQA ratios, windows, precisions, and
    ragged per-slot kv_len including an empty slot."""
    rng = np.random.RandomState(0)
    b, s, hq, d = 4, 24, 4, 16
    q, k, v, q_pos, pos, kvl = _slot_case(
        rng, b, s, hq, hkv, d, kv_lens=[0, 3, s, 10], pads=[0, 1, 2, 3])
    if precision == "int8":
        k, v = qz.quant_kv(k), qz.quant_kv(v)
    out_ref = ops.decode_attention(q, k, v, q_pos, pos, window=window,
                                   kv_len=kvl, force="ref")
    out_int = ops.decode_attention(q, k, v, q_pos, pos, window=window,
                                   kv_len=kvl, force="interpret")
    np.testing.assert_allclose(np.asarray(out_int), np.asarray(out_ref),
                               atol=1e-5)
    # empty slot (kv_len == 0): exactly zero on both paths
    assert np.all(np.asarray(out_ref)[0] == 0)
    assert np.all(np.asarray(out_int)[0] == 0)


@pytest.mark.parametrize("precision", ["float", "int8"])
def test_flash_decode_parity_unbounded(precision):
    """kv_len=None (no bound: plain masked decode) still matches."""
    rng = np.random.RandomState(1)
    b, s, hq, hkv, d = 2, 17, 4, 2, 8      # ragged S exercises padding
    q, k, v, q_pos, pos, _ = _slot_case(
        rng, b, s, hq, hkv, d, kv_lens=[5, s], pads=[0, 2])
    if precision == "int8":
        k, v = qz.quant_kv(k), qz.quant_kv(v)
    out_ref = ops.decode_attention(q, k, v, q_pos, pos, force="ref")
    out_int = ops.decode_attention(q, k, v, q_pos, pos, force="interpret")
    np.testing.assert_allclose(np.asarray(out_int), np.asarray(out_ref),
                               atol=1e-5)


def test_flash_decode_parity_ring_positions():
    """Sliding-window ring layout: positions wrap (slot = pos % w), the
    newest entries overwrite the oldest — masking is purely
    position-driven, so order in the cache must not matter."""
    rng = np.random.RandomState(2)
    b, w, hq, hkv, d = 2, 8, 4, 2, 16
    q = jnp.asarray(rng.randn(b, 1, hq, d), jnp.float32)
    k = jnp.asarray(rng.randn(b, w, hkv, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, w, hkv, d), jnp.float32)
    # row 0: wrapped ring at position 11 (slots hold pos 8..11, 4..7)
    # row 1: part-filled ring at position 2
    pos = np.array([[8, 9, 10, 11, 4, 5, 6, 7],
                    [0, 1, 2, -1, -1, -1, -1, -1]], np.int32)
    q_pos = jnp.asarray([11, 2], jnp.int32)
    kvl = jnp.asarray([w, 3], jnp.int32)
    out_ref = ops.decode_attention(q, k, v, q_pos, jnp.asarray(pos),
                                   window=w, kv_len=kvl, force="ref")
    out_int = ops.decode_attention(q, k, v, q_pos, jnp.asarray(pos),
                                   window=w, kv_len=kvl, force="interpret")
    np.testing.assert_allclose(np.asarray(out_int), np.asarray(out_ref),
                               atol=1e-5)


def test_kv_len_blocks_really_skipped():
    """Poison the cache beyond kv_len with valid-looking entries: the
    kernel must not read them (the bound is a skip, not a mask), and the
    ref applies the same index bound."""
    rng = np.random.RandomState(3)
    b, s, hq, hkv, d = 2, 32, 4, 2, 16
    q, k, v, q_pos, pos, kvl = _slot_case(
        rng, b, s, hq, hkv, d, kv_lens=[6, 9], pads=[0, 0])
    clean = [ops.decode_attention(q, k, v, q_pos, pos, kv_len=kvl,
                                  force=f) for f in ("ref", "interpret")]
    # poison: attendable positions + huge values in the dead tail
    pos_bad = np.asarray(pos).copy()
    k_bad, v_bad = np.asarray(k).copy(), np.asarray(v).copy()
    for i, n in enumerate(np.asarray(kvl)):
        pos_bad[i, n:] = 0                      # pos 0 <= q_pos: attendable
        k_bad[i, n:] = 100.0
        v_bad[i, n:] = 100.0
    for f, want in zip(("ref", "interpret"), clean):
        got = ops.decode_attention(q, jnp.asarray(k_bad), jnp.asarray(v_bad),
                                   q_pos, jnp.asarray(pos_bad), kv_len=kvl,
                                   force=f)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want),
                                      err_msg=f)


# ---------------------------------------------------------------------------
# Serving through the kernel: token-exact with kernel_path=interpret
# ---------------------------------------------------------------------------
@pytest.fixture()
def interpret_path():
    old = flags.get("kernel_path")
    flags.set_flags(kernel_path="interpret")
    yield
    flags.set_flags(kernel_path=old)


def _setup(arch):
    cfg = dataclasses.replace(configs.get_smoke(arch), dtype="float32")
    return cfg, init_params(cfg, jax.random.key(0))


def _reference_decode(cfg, params, prompt, max_new, policy=None):
    """Contiguous no-batching decode on whatever kernel path is pinned."""
    fns = api.model_fns(cfg)
    logits, cache = fns.forward_prefill(
        cfg, params, {"tokens": jnp.asarray(prompt[None, :])}, policy)
    cache = grow_cache(cfg, cache, max_new + 1)
    out = [int(jnp.argmax(logits, -1)[0])]
    pos = len(prompt)
    for _ in range(max_new - 1):
        logits, cache = fns.forward_decode(
            cfg, params, cache, jnp.asarray([out[-1]], jnp.int32),
            jnp.asarray([pos], jnp.int32), policy=policy)
        out.append(int(jnp.argmax(logits, -1)[0]))
        pos += 1
    return out


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma3-4b"])
def test_continuous_serving_interpret_float(arch, interpret_path):
    """Slot-recycled serving through the Pallas (interpret) decode kernel
    — per-slot kv_len bounding, chunked pad-free prefill, ring caches —
    token-exact vs an unpadded contiguous decode on the same path."""
    cfg, params = _setup(arch)
    rng = np.random.RandomState(5)
    lens, budgets = [3, 9, 6], [4, 3, 5]
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    srv = ContinuousBatchServer(cfg, params, slots=2, max_prompt=16,
                                prefill_chunk=4, max_new_tokens=8)
    reqs = srv.submit(prompts, max_new_tokens=budgets)
    srv.run()
    for r, p, bud in zip(reqs, prompts, budgets):
        assert r.tokens == _reference_decode(cfg, params, p, bud), \
            f"rid {r.rid}: kernel-path serving diverged from reference"


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma3-4b"])
def test_continuous_serving_interpret_int8_vs_fakequant(arch,
                                                        interpret_path):
    """ACCEPTANCE: native int8 serving with the decode kernel forced on
    == the fake-quant float oracle.  The oracle's float cache holds
    exactly the dequantized int8 values, so if the kernel's in-tile
    dequant is faithful (and nothing dequantizes the cache outside the
    tile) the two runs are bit-identical → token-exact."""
    cfg, params = _setup(arch)
    rng = np.random.RandomState(6)
    lens, budgets = [3, 8, 5], [4, 3, 5]
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    srv = ContinuousBatchServer(cfg, params, slots=2, max_prompt=16,
                                prefill_chunk=4, max_new_tokens=8,
                                precision="int8")
    reqs = srv.submit(prompts, max_new_tokens=budgets)
    srv.run()
    oracle = ContinuousBatchServer(cfg, params, slots=2, max_prompt=16,
                                   prefill_chunk=4, max_new_tokens=8,
                                   precision="int8_fakequant")
    oreqs = oracle.submit(prompts, max_new_tokens=budgets)
    oracle.run()
    assert [r.tokens for r in reqs] == [r.tokens for r in oreqs], \
        "native int8 decode kernel diverged from the fake-quant oracle"


def test_int8_decode_never_dequantizes_cache(monkeypatch):
    """The int8 decode path must not call ``dequant_kv`` at all — dequant
    happens only inside the kernel tile / per-tile ref scan.  (The
    fake-quant *oracle* legitimately round-trips the single new (B, 1)
    KV entry at write time; the native path doesn't even do that.)"""
    calls = []
    real = qz.dequant_kv

    def spy(kv, dtype=jnp.float32):
        calls.append(tuple(kv.q.shape))
        return real(kv, dtype)

    monkeypatch.setattr("repro.models.layers.dequant_kv", spy)
    cfg, params = _setup("internlm2-1.8b")
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab_size, 5).astype(np.int32)
               for _ in range(2)]
    srv = ContinuousBatchServer(cfg, params, slots=2, max_prompt=8,
                                max_new_tokens=4, precision="int8")
    srv.submit(prompts)
    srv.run()
    assert calls == [], \
        f"int8 decode materialized dequantized KV: shapes {calls}"
