"""Platform-core tests: impulse, quantize, estimator, compiler, tuner,
calibration, active learning."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import calibration as cal
from repro.core import estimator as est
from repro.core import quantize as qz
from repro.core.active_learning import (ProximityLabeler,
                                        active_learning_round, pca_2d)
from repro.core.blocks import make_dsp_block, make_learn_block
from repro.core.eon_compiler import compile_impulse
from repro.core.impulse import Impulse
from repro.core.tuner import EONTuner
from repro.data.synthetic import event_stream, keyword_audio


N_SAMPLES = 4000


@pytest.fixture(scope="module")
def kws_data():
    from repro.data.dataset import Dataset
    ds = Dataset()
    ds.add_many(keyword_audio(n_per_class=18, n_classes=3,
                              n_samples=N_SAMPLES))
    return ds


@pytest.fixture(scope="module")
def trained_impulse(kws_data):
    imp = Impulse(make_dsp_block("mfcc", n_mels=32, n_coeffs=10),
                  make_learn_block("conv1d-stack", n_blocks=2, ch_first=16,
                                   ch_last=32, n_classes=3),
                  input_shape=N_SAMPLES)
    imp.init(jax.random.key(0))
    xtr, ytr = kws_data.arrays("train")
    imp.fit((np.asarray(xtr), np.asarray(ytr)), epochs=5, batch_size=16,
            lr=2e-3)
    return imp


def test_impulse_trains(trained_impulse, kws_data):
    xte, yte = kws_data.arrays("test")
    acc = trained_impulse.evaluate(trained_impulse.params,
                                   np.asarray(xte), np.asarray(yte))
    assert acc >= 0.7, acc


def test_int8_quantization_accuracy(trained_impulse, kws_data):
    """Paper Table 4: int8 stays within a few points of float."""
    xte, yte = kws_data.arrays("test")
    xtr, _ = kws_data.arrays("train")
    trained_impulse.quantize(np.asarray(xtr[:16]))
    f32 = trained_impulse.evaluate(trained_impulse.params,
                                   np.asarray(xte), np.asarray(yte))
    i8 = trained_impulse.int8_accuracy(np.asarray(xte), np.asarray(yte))
    assert i8 >= f32 - 0.1, (f32, i8)
    assert trained_impulse.qparams.meta["compression"] > 2.5


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 2 ** 31 - 1), st.integers(2, 4))
def test_quantize_roundtrip_error_bound(seed, ndim):
    """Property: per-channel int8 round trip error <= scale/2 = amax/254."""
    rng = np.random.RandomState(seed % (2 ** 31))
    shape = tuple(rng.randint(2, 8) for _ in range(ndim))
    w = jnp.asarray(rng.randn(*shape) * rng.uniform(0.01, 10), jnp.float32)
    qp = qz.quantize_params({"w": w})
    fq = qz.fake_quant_params(qp)["w"]
    axes = tuple(range(w.ndim - 1))
    amax = np.max(np.abs(np.asarray(w)), axis=axes, keepdims=True)
    bound = amax / 254.0 + 1e-7
    assert np.all(np.abs(np.asarray(w - fq)) <= bound + 1e-6)


def test_qat_ste_gradient_is_identity():
    w = jnp.asarray(np.random.RandomState(0).randn(8, 8), jnp.float32)
    g = jax.grad(lambda p: jnp.sum(qz.fake_quant_ste(p) ** 2))(w)
    expect = jax.grad(lambda p: jnp.sum(
        (p + jax.lax.stop_gradient(qz.fake_quant_ste(p) - p)) ** 2))(w)
    np.testing.assert_allclose(g, expect)


def test_estimator_engine_ordering(trained_impulse):
    """EON must beat TFLM on RAM and flash (Table 4's claim)."""
    for int8 in (False, True):
        tflm = est.estimate_impulse(trained_impulse, "nano33ble",
                                    engine="tflm", int8=int8)
        eon = est.estimate_impulse(trained_impulse, "nano33ble",
                                   engine="eon", int8=int8)
        assert eon.ram_kb < tflm.ram_kb
        assert eon.flash_kb < tflm.flash_kb
    # int8 must beat float on flash and nn latency (Table 2/4)
    f = est.estimate_impulse(trained_impulse, "nano33ble", int8=False)
    q = est.estimate_impulse(trained_impulse, "nano33ble", int8=True)
    assert q.flash_kb < f.flash_kb
    assert q.nn_latency_ms < f.nn_latency_ms


def test_estimator_cross_target_ordering(trained_impulse):
    """Float inference: M4 (FPU) beats M0+ (soft float) — Table 2 shape."""
    m4 = est.estimate_impulse(trained_impulse, "nano33ble", int8=False)
    m0 = est.estimate_impulse(trained_impulse, "rp2040", int8=False)
    assert m4.nn_latency_ms < m0.nn_latency_ms


def test_eon_compiler_roundtrip(trained_impulse):
    art = compile_impulse(trained_impulse, batch_size=1)
    fn = art.rehydrate()
    x = np.asarray(keyword_audio(n_per_class=1, n_classes=1,
                                 n_samples=N_SAMPLES)[0].data)[None]
    np.testing.assert_allclose(np.asarray(fn(x)),
                               np.asarray(trained_impulse.logits(x)),
                               atol=1e-4)
    assert art.artifact_bytes > 0


def test_eon_tuner_screen_respects_constraints(kws_data):
    tuner = EONTuner(input_samples=N_SAMPLES, n_classes=3,
                     target="nano33ble", max_ram_kb=64, max_flash_kb=256)
    cands = tuner.sample(8)
    survivors = tuner.screen(cands)
    for c in survivors:
        assert c.estimate.ram_kb <= 64
        assert c.estimate.flash_kb <= 256
    assert all(c.estimate is not None for c in cands)


def test_calibration_pareto_front():
    scores, spans = event_stream(n_windows=6000, n_events=25, seed=3)
    front = cal.calibrate(scores, spans, generations=6, population=16)
    assert front
    fars = [p["far_per_hour"] for p in front]
    frrs = [p["frr"] for p in front]
    # pareto: sorted by FAR ascending, FRR must be strictly descending-ish
    assert fars == sorted(fars)
    assert all(frrs[i] >= frrs[i + 1] for i in range(len(frrs) - 1))
    # a sane config catches most events at low FAR somewhere on the front
    assert min(frrs) <= 0.2


def test_calibration_threshold_monotonicity():
    """Property: raising the threshold cannot raise FAR."""
    scores, spans = event_stream(n_windows=4000, n_events=15, seed=1)
    fars = []
    for th in (0.3, 0.5, 0.7, 0.9):
        cfg = cal.PostProcessConfig(3, th, 5)
        far, _ = cal.far_frr(scores, spans, cfg, windows_per_hour=3600)
        fars.append(far)
    assert all(fars[i] >= fars[i + 1] for i in range(len(fars) - 1))


def test_active_learning_labels_clusters():
    rng = np.random.RandomState(0)
    n_per, d, classes = 60, 16, 3
    centers = rng.randn(classes, d) * 6
    xs = np.concatenate([centers[c] + rng.randn(n_per, d)
                         for c in range(classes)])
    ys = np.repeat(np.arange(classes), n_per)
    labeled_idx = np.concatenate([np.where(ys == c)[0][:8]
                                  for c in range(classes)])
    out = active_learning_round(lambda x: x, xs, labeled_idx, ys, classes)
    prop, conf = out["proposed"], out["confident"]
    mask = conf & (prop >= 0)
    acc = (prop[mask] == ys[mask]).mean()
    assert acc >= 0.95, acc
    assert mask.mean() > 0.5          # labels most of the pool
    assert out["projection"].shape == (len(xs), 2)
