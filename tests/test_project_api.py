"""Project API façade (paper §4.9) + custom-block extensibility."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.blocks import (make_dsp_block, make_learn_block,
                               register_dsp_block, register_learn_block)
from repro.core.project import Project
from repro.data.synthetic import keyword_audio

N = 4000


def test_project_full_workflow(tmp_path):
    p = Project("kws", tmp_path)
    v = p.ingest(keyword_audio(n_per_class=24, n_classes=3, n_samples=N))
    assert len(p.dataset.versions()) == 1
    p.set_impulse("mfcc", {"n_mels": 32, "n_coeffs": 10},
                  "conv1d-stack", {"n_blocks": 2, "ch_first": 16,
                                   "ch_last": 32})
    p.train(epochs=8)
    res = p.test()
    assert res["accuracy"] >= 0.6
    meta = p.quantize()
    assert meta["compression"] > 2
    e = p.estimate("nano33ble")
    assert e.fits
    art = p.deploy(tmp_path / "deploy.bin", int8=True)
    assert (tmp_path / "deploy.bin").exists()
    assert art.artifact_bytes > 0
    stages = p.summary()["stages_run"]
    for s in ("ingest", "set_impulse", "train", "test", "quantize",
              "estimate", "deploy"):
        assert s in stages
    # the log is persisted (API-driven automation record)
    assert (tmp_path / "project_log.json").exists()


def test_custom_dsp_block_registration():
    @dataclasses.dataclass(frozen=True)
    class DecimateBlock:
        factor: int = 4
        name: str = "decimate"

        def feature_shape(self, n):
            return (n // self.factor,)

        def __call__(self, x):
            return x[..., ::self.factor]

        def hyperparams(self):
            return {"factor": self.factor}

    register_dsp_block("decimate", DecimateBlock)
    blk = make_dsp_block("decimate", factor=2)
    x = jnp.arange(16, dtype=jnp.float32)[None]
    out = blk.apply(x)
    assert out.shape == (1, 8)
    assert blk.feature_shape(16) == (8,)


def test_custom_learn_block_registration():
    @dataclasses.dataclass(frozen=True)
    class LinearCfg:
        n_classes: int = 3
        name: str = "linear"

    def init(cfg, key, input_shape):
        din = int(np.prod(input_shape))
        return {"w": jax.random.normal(key, (din, cfg.n_classes)) * 0.01}

    def apply(cfg, params, feats):
        return feats.reshape(feats.shape[0], -1) @ params["w"]

    register_learn_block("linear", LinearCfg, init, apply)
    blk = make_learn_block("linear", n_classes=3)
    params = blk.init(jax.random.key(0), (10, 4))
    logits = blk.apply(params, jnp.ones((2, 10, 4)))
    assert logits.shape == (2, 3)


def test_unknown_block_raises():
    with pytest.raises(ValueError, match="unknown dsp block"):
        make_dsp_block("nope")
    with pytest.raises(ValueError, match="unknown learn block"):
        make_learn_block("nope")
