"""Sharding policy unit tests + HLO analyzer correctness."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.roofline.collect import (analyze_module, parse_module,
                                    scan_trip_counts)
from repro.roofline.hw import V5E
from repro.roofline.model import RooflineReport
from repro.sharding.policy import logical_to_pspec, make_rules


class FakeMesh:
    """Duck-typed mesh for pspec unit tests (shape dict only)."""
    def __init__(self, shape):
        self.shape = shape


RULES = make_rules("tp")
MESH = FakeMesh({"data": 16, "model": 16})


def test_pspec_basic():
    spec = logical_to_pspec(("p_dmodel", "p_heads"), RULES, MESH,
                            (4096, 2048))
    assert spec == P("data", "model")


def test_pspec_divisibility_fallback():
    # 4 kv heads can't split 16 ways -> replicated
    spec = logical_to_pspec(("act_batch", "act_kv_seq", "act_kv_heads", None),
                            RULES, MESH, (32, 1024, 4, 128))
    assert spec == P("data")


def test_pspec_no_double_axis_use():
    rules = make_rules("tp", decode=True)
    # batch takes "data"; cache_seq falls back to the remaining "model"
    spec = logical_to_pspec(("act_batch", "act_cache_seq", None, None),
                            rules, MESH, (128, 32768, 8, 128))
    assert spec == P("data", "model")
    # batch=1 can't use "data" -> cache seq gets both axes
    spec = logical_to_pspec(("act_batch", "act_cache_seq", None, None),
                            rules, MESH, (1, 524288, 8, 128))
    assert spec == P(None, ("data", "model"))


def test_strategies_differ():
    tp = make_rules("tp")
    cp = make_rules("cp")
    sp = make_rules("tp_sp")
    assert tp["act_heads"] == "model" and cp["act_heads"] is None
    assert cp["act_seq"] == "model"
    assert sp["act_res_seq"] == "model" and tp["act_res_seq"] is None


# ---------------------------------------------------------------------------
# HLO analyzer
# ---------------------------------------------------------------------------
def test_analyzer_loop_weighting_exact():
    """Weighted dot flops == analytic for a scanned matmul chain; the
    raw cost_analysis is known NOT to weight loops."""
    w = jax.ShapeDtypeStruct((12, 256, 128), jnp.float32)
    x = jax.ShapeDtypeStruct((8, 256), jnp.float32)

    def f(ws, x):
        def body(h, w):
            return jnp.tanh(h @ w @ w.T), None
        return jax.lax.scan(body, x, ws)[0]

    comp = jax.jit(f).lower(w, x).compile()
    wc = analyze_module(comp.as_text())
    expect = 12 * (2 * 8 * 256 * 128 + 2 * 8 * 128 * 256)
    assert wc.flops == expect
    from repro.core.eon_compiler import normalize_cost_analysis
    raw = normalize_cost_analysis(comp.cost_analysis())
    assert raw["flops"] < expect         # the raw one undercounts


def test_analyzer_trip_counts():
    x = jax.ShapeDtypeStruct((4, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((7, 16, 16), jnp.float32)

    def f(ws, x):
        return jax.lax.scan(lambda h, w: (h @ w, None), x, ws)[0]

    txt = jax.jit(f).lower(w, x).compile().as_text()
    assert 7 in scan_trip_counts(txt)


def test_analyzer_bytes_min_le_bytes():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def f(a):
        return jnp.tanh(a @ a) + jnp.exp(a)

    wc = analyze_module(jax.jit(f).lower(x).compile().as_text())
    assert 0 < wc.bytes_min <= wc.bytes_accessed


def test_roofline_report_terms():
    rep = RooflineReport(
        arch="x", shape="train_4k", mesh="16x16", n_chips=256,
        hlo_flops=197e12,            # exactly one second of compute
        hlo_bytes=819e9 * 2,         # two seconds of memory (upper)
        hlo_bytes_min=819e9 * 0.5,   # half a second (lower)
        collective_bytes=200e9 * 0.25,
        collective_detail={}, per_device_hbm=8 * 2 ** 30,
        model_flops=197e12 * 256 * 0.5,
    ).finalize(V5E)
    assert abs(rep.t_compute - 1.0) < 1e-6
    assert rep.bottleneck == "compute"        # judged vs the lower bound
    assert abs(rep.useful_flops_ratio - 0.5) < 1e-6
    assert rep.fits_hbm
    assert abs(rep.roofline_fraction - 0.5) < 1e-6
