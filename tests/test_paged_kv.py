"""Paged KV cache: block-table memory manager end to end (docs/paged_kv.md).

The contracts under test:

* ``kv_block_size`` is the single source of block granularity (server
  capacity rounding == kernel tile == pool block).
* ``BlockManager``: free-list alloc/free with refcounts, exhaustion,
  hash-chain prefix caching (match capped at prompt − 1, registry holds
  its own reference, LRU reclaim under pressure).
* Kernel parity *through the block table*: interpret-mode Pallas
  ``flash_decode``/``flash_chunk_prefill`` against the ref oracle that
  gathers through the same table — scrambled physical placements,
  ragged ``kv_len``, empty slots, Int8KV — so the paged addressing
  itself is pinned, not just the softmax math.
* Paged continuous serving is token-exact vs the unpadded one-shot
  reference on {uniform, ring, ssm, hybrid} × {float, int8}, including
  forced preempt-and-recompute and physical prefix sharing (asserted by
  pool accounting: live blocks < Σ per-request blocks).
* Slot/block recycling under churn — release → re-admit → preemption →
  re-prefill — is token-identical, including the gemma3 sliding-window
  ring (freed blocks reusable immediately).
* The paged AOT artifact carries ``block_table`` in its signature and
  pool pricing in its resource report.
"""
import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels import flash_decode as fd
from repro.kernels import ref
from repro.models import api
from repro.models.params import init_params
from repro.models.transformer import grow_cache
from repro.serve.kvcache import (BlockManager, PoolExhausted,
                                 abstract_paged_cache, kv_block_size,
                                 kv_pool_block_bytes, paged_cache_keys)
from repro.serve.server import ContinuousBatchServer, PagedBatchServer


@functools.lru_cache(maxsize=None)
def _setup(arch):
    cfg = dataclasses.replace(configs.get_smoke(arch), dtype="float32")
    return cfg, init_params(cfg, jax.random.key(0))


def _reference_decode(cfg, params, prompt, max_new):
    fns = api.model_fns(cfg)
    logits, cache = fns.forward_prefill(
        cfg, params, {"tokens": jnp.asarray(prompt[None, :])})
    cache = grow_cache(cfg, cache, max_new + 1)
    out = [int(jnp.argmax(logits, -1)[0])]
    pos = len(prompt)
    for _ in range(max_new - 1):
        logits, cache = fns.forward_decode(
            cfg, params, cache, jnp.asarray([out[-1]], jnp.int32),
            jnp.asarray([pos], jnp.int32))
        out.append(int(jnp.argmax(logits, -1)[0]))
        pos += 1
    return out


# ---------------------------------------------------------------------------
# kv_block_size: one helper, three consumers
# ---------------------------------------------------------------------------
def test_kv_block_size_is_shared():
    """The dedupe contract: the server's effective KV block equals the
    helper (which equals the kernels' tile choice) at every capacity."""
    for cap, want in ((64, 64), (128, 128), (192, 64), (320, 64),
                      (72, 72), (144, 16), (8, 8)):
        assert kv_block_size(cap) == want, cap
    cfg, params = _setup("internlm2-1.8b")
    srv = ContinuousBatchServer(cfg, params, slots=1, max_prompt=16,
                                max_new_tokens=4)
    assert srv._kv_block == kv_block_size(srv.capacity)
    psrv = PagedBatchServer(cfg, params, slots=1, max_prompt=16,
                            max_new_tokens=4)
    assert psrv.block_size == kv_block_size(psrv.capacity)
    # per-block pricing honors a block_size override (a 256-row block
    # costs exactly 2x a 128-row block — the inner abstract pool must
    # not silently re-derive kv_block_size(256) == 128)
    assert kv_pool_block_bytes(cfg, 256, None, 256) \
        == 2 * kv_pool_block_bytes(cfg, 256, None, 128)


# ---------------------------------------------------------------------------
# BlockManager (host-side, no model)
# ---------------------------------------------------------------------------
def test_block_manager_alloc_free_refcount():
    m = BlockManager(4, 8, prefix_cache=False)
    a = m.alloc(3)
    assert len(set(a)) == 3 and m.free_blocks == 1 and m.live_blocks == 3
    m.free(a[:1])
    assert m.free_blocks == 2
    b = m.alloc(2)
    assert m.free_blocks == 0
    with pytest.raises(PoolExhausted):
        m.alloc(1)
    m.free(a[1:])
    m.free(b)
    assert m.free_blocks == 4 and m.live_blocks == 0
    with pytest.raises(AssertionError):
        m.free(b[:1])                      # double free


def test_block_manager_prefix_cache():
    m = BlockManager(8, 4)
    toks = np.arange(13, dtype=np.int32)   # 3 full blocks + 1 spare token
    blocks = m.alloc(4)
    m.register_prefix(toks, blocks)        # registers blocks 0..2 (3 full)
    assert m.live_blocks == 8 - m.free_blocks
    m.free(blocks)                         # writer releases; cache holds 3
    assert m.free_blocks == 5
    # identical prompt: match capped at len-1 => (13-1)//4 = 3 full blocks
    hit = m.match_prefix(toks)
    assert hit == blocks[:3]
    # exactly block-aligned prompt of 12: cap (12-1)//4 = 2 blocks — the
    # last block must be recomputed to produce logits
    assert m.match_prefix(toks[:12]) == blocks[:2]
    m.free(hit)
    m.free(blocks[:2])
    # diverging prompt: only the shared leading blocks match
    other = toks.copy()
    other[5] = 999
    assert m.match_prefix(other) == blocks[:1]
    m.free(blocks[:1])
    # pool pressure reclaims cached-but-unreferenced blocks (LRU)
    taken = m.alloc(8)
    assert m.free_blocks == 0 and m.stats["reclaimed"] == 3
    assert m.match_prefix(toks) == []      # registry emptied by reclaim
    m.free(taken)


# ---------------------------------------------------------------------------
# Kernel parity through the block table (interpret vs gather-ref)
# ---------------------------------------------------------------------------
def _paged_case(rng, b, n_tbl, nb, bs, hkv, d, fills, *, int8=False):
    """Scrambled physical placement: slot rows map to a shuffled set of
    pool blocks; pool entries outside any live region keep poisoned
    positions/values (they must never be read thanks to kv_len)."""
    kp = rng.randn(nb, bs, hkv, d).astype(np.float32)
    vp = rng.randn(nb, bs, hkv, d).astype(np.float32)
    pos = rng.randint(0, 3, (nb, bs)).astype(np.int32)   # poison
    table = np.zeros((b, n_tbl), np.int32)
    order = rng.permutation(nb)
    nxt = 0
    for i, fill in enumerate(fills):
        for j in range(-(-fill // bs) if fill else 0):
            blk = int(order[nxt]); nxt += 1
            table[i, j] = blk
            n = min(bs, fill - j * bs)
            pos[blk, :n] = np.arange(j * bs, j * bs + n)
            pos[blk, n:] = -1
    out = dict(k=jnp.asarray(kp), v=jnp.asarray(vp),
               pos=jnp.asarray(pos), table=jnp.asarray(table),
               kvl=jnp.asarray(fills, jnp.int32))
    if int8:
        out["ks"] = jnp.asarray(
            rng.uniform(0.01, 0.1, (nb, bs, hkv)).astype(np.float32))
        out["vs"] = jnp.asarray(
            rng.uniform(0.01, 0.1, (nb, bs, hkv)).astype(np.float32))
        out["k"] = jnp.asarray(rng.randint(-127, 128, kp.shape), jnp.int8)
        out["v"] = jnp.asarray(rng.randint(-127, 128, vp.shape), jnp.int8)
    return out


@pytest.mark.parametrize("int8", [False, True])
@pytest.mark.parametrize("g", [1, 2])
def test_paged_flash_decode_parity(int8, g):
    rng = np.random.RandomState(0)
    b, hkv, d, bs, n_tbl, nb = 4, 2, 16, 8, 4, 9
    fills = np.array([5, 0, 32, 17], np.int32)   # ragged + empty + full
    c = _paged_case(rng, b, n_tbl, nb, bs, hkv, d, fills, int8=int8)
    q = rng.randn(b, hkv, g, d).astype(np.float32)
    qp = jnp.asarray(np.maximum(fills - 1, 0), jnp.int32)
    scales = dict(k_scale=c.get("ks"), v_scale=c.get("vs"))
    got = fd.flash_decode(jnp.asarray(q), c["k"], c["v"], qp, c["pos"],
                          c["kvl"], block_table=c["table"],
                          interpret=True, **scales)
    q_ref = q.reshape(b, hkv * g, d)[:, None]
    want = ref.paged_decode_attention_ref(
        jnp.asarray(q_ref), c["k"], c["v"], qp, c["pos"], c["table"],
        c["kvl"], **scales)
    np.testing.assert_allclose(np.asarray(got).reshape(b, hkv * g, d),
                               np.asarray(want)[:, 0], atol=2e-5)
    assert np.abs(np.asarray(got)[1]).max() == 0.0   # empty slot → zeros


@pytest.mark.parametrize("int8", [False, True])
def test_paged_chunk_prefill_parity(int8):
    rng = np.random.RandomState(1)
    b, hkv, g, cq, d, bs, n_tbl, nb = 3, 2, 2, 4, 16, 8, 4, 8
    fills = np.array([8, 20, 12], np.int32)      # post-write fills p + C
    c = _paged_case(rng, b, n_tbl, nb, bs, hkv, d, fills, int8=int8)
    # chunk queries at the tail of each fill; one ragged row (2 pads)
    qpos = np.full((b, cq), -1, np.int32)
    reals = (4, 4, 2)
    for i, (f, r) in enumerate(zip(fills, reals)):
        qpos[i, :r] = np.arange(f - r, f)
    q = rng.randn(b, hkv, cq * g, d).astype(np.float32)
    qp_rows = np.repeat(qpos, g, axis=1)         # (B, C·G), (query, group)
    scales = dict(k_scale=c.get("ks"), v_scale=c.get("vs"))
    got = fd.flash_chunk_prefill(
        jnp.asarray(q), c["k"], c["v"], jnp.asarray(qp_rows), c["pos"],
        c["kvl"], block_table=c["table"], interpret=True, **scales)
    q_ref = q.reshape(b, hkv, cq, g, d).transpose(0, 2, 1, 3, 4) \
        .reshape(b, cq, hkv * g, d)
    want = ref.paged_chunk_attention_ref(
        jnp.asarray(q_ref), c["k"], c["v"], jnp.asarray(qpos), c["pos"],
        c["table"], c["kvl"], **scales)
    want = np.asarray(want).reshape(b, cq, hkv, g, d) \
        .transpose(0, 2, 1, 3, 4).reshape(b, hkv, cq * g, d)
    np.testing.assert_allclose(np.asarray(got), want, atol=2e-5)
    # pad query rows (grouped rows c·G + g with c >= reals) → exact zeros
    assert np.abs(np.asarray(got)[2][:, 2 * g:, :]).max() == 0.0


# ---------------------------------------------------------------------------
# Serving: token-exact on every family × precision (ACCEPTANCE)
# ---------------------------------------------------------------------------
_LENS, _BUDGETS = (5, 12, 9, 3, 16), (6, 4, 8, 5, 3)


def _workload(cfg, seed=3):
    rng = np.random.RandomState(seed)
    return [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
            for n in _LENS]


_PAGED_KW = dict(slots=2, max_prompt=16, prefill_chunk=4,
                 max_new_tokens=8, block_size=8)


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma3-4b",
                                  "falcon-mamba-7b", "zamba2-2.7b"])
def test_paged_serving_token_exact_float(arch):
    """ACCEPTANCE: paged continuous serving — block tables, multi-block
    slots, slot recycling — is token-exact vs the unpadded one-shot
    reference on uniform, ring, SSM, and hybrid families."""
    cfg, params = _setup(arch)
    prompts = _workload(cfg)
    srv = PagedBatchServer(cfg, params, **_PAGED_KW)
    reqs = srv.submit(prompts, max_new_tokens=list(_BUDGETS))
    srv.run()
    for r, p, b in zip(reqs, prompts, _BUDGETS):
        assert r.tokens == _reference_decode(cfg, params, p, b), \
            f"{arch} rid {r.rid} diverged"


@pytest.mark.parametrize("arch", ["internlm2-1.8b", "gemma3-4b",
                                  "falcon-mamba-7b", "zamba2-2.7b"])
def test_paged_serving_token_exact_int8(arch):
    """ACCEPTANCE: native int8 paged serving == the fake-quant float
    oracle through the same paged schedule on every family."""
    cfg, params = _setup(arch)
    prompts = _workload(cfg, seed=6)
    srv = PagedBatchServer(cfg, params, precision="int8", **_PAGED_KW)
    reqs = srv.submit(prompts, max_new_tokens=list(_BUDGETS))
    srv.run()
    fq = PagedBatchServer(cfg, params, precision="int8_fakequant",
                          **_PAGED_KW)
    freqs = fq.submit(prompts, max_new_tokens=list(_BUDGETS))
    fq.run()
    assert [r.tokens for r in reqs] == [r.tokens for r in freqs], \
        f"{arch}: int8 diverged from fake-quant oracle"


@pytest.mark.parametrize("precision", ["float", "int8"])
def test_paged_forced_preemption_token_exact(precision):
    """ACCEPTANCE: a pool too small for the workload forces at least one
    preempt-and-recompute, and the token streams still match the
    reference (float) / fake-quant oracle (int8) exactly."""
    cfg, params = _setup("internlm2-1.8b")
    rng = np.random.RandomState(5)
    lens, budgets = [14, 15, 13], [12, 12, 12]
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    kw = dict(slots=3, max_prompt=16, prefill_chunk=4, max_new_tokens=12,
              block_size=8, pool_blocks=8, prefix_cache=False)
    srv = PagedBatchServer(cfg, params, precision=precision, **kw)
    reqs = srv.submit(prompts, max_new_tokens=budgets)
    m = srv.run()
    assert m["preemptions"] > 0, "pool never ran dry — test is vacuous"
    if precision == "float":
        refs = [_reference_decode(cfg, params, p, b)
                for p, b in zip(prompts, budgets)]
    else:
        fq = PagedBatchServer(cfg, params, precision="int8_fakequant",
                              **kw)
        fq.submit(prompts, max_new_tokens=budgets)
        mf = fq.run()
        assert mf["preemptions"] > 0
        refs = [r.tokens for r in fq.requests.values()]
    assert [r.tokens for r in reqs] == refs, \
        "preempt-and-recompute diverged"


def test_paged_prefix_sharing_physical_and_exact():
    """ACCEPTANCE: two live requests sharing a prompt prefix physically
    share pool blocks — live blocks strictly below the sum of
    per-request block needs — and both streams match the reference."""
    cfg, params = _setup("internlm2-1.8b")
    rng = np.random.RandomState(9)
    base = rng.randint(0, cfg.vocab_size, 16).astype(np.int32)
    srv = PagedBatchServer(cfg, params, slots=2, max_prompt=24,
                           prefill_chunk=8, max_new_tokens=6,
                           block_size=8)
    # warm the prefix cache: one request over the shared prefix
    a, = srv.submit([base], max_new_tokens=[4])
    srv.run()
    assert a.tokens == _reference_decode(cfg, params, base, 4)
    # two concurrent requests extending the same prefix
    pb = np.concatenate([base, rng.randint(0, cfg.vocab_size, 4)
                         .astype(np.int32)])
    pc = np.concatenate([base, rng.randint(0, cfg.vocab_size, 2)
                         .astype(np.int32)])
    rb, rc = srv.submit([pb, pc], max_new_tokens=[5, 5])
    m = srv.run()
    assert m["prefix_hit_blocks"] > 0
    assert rb.tokens == _reference_decode(cfg, params, pb, 5)
    assert rc.tokens == _reference_decode(cfg, params, pc, 5)
    # pool accounting: while B and C were both live, the shared blocks
    # were counted once — peak live < what two private copies would need
    bs = srv.block_size
    private = sum(-(-(len(p) + 5) // bs) for p in (pb, pc))
    assert m["pool_live_blocks_peak"] < private + 0, \
        (m["pool_live_blocks_peak"], private)


@pytest.mark.parametrize("arch,precision", [
    ("internlm2-1.8b", "float"), ("internlm2-1.8b", "int8"),
    ("gemma3-4b", "float"), ("gemma3-4b", "int8"),
])
def test_paged_churn_recycling(arch, precision):
    """Slot/block recycling under churn: release → re-admit → forced
    preemption → re-prefill on ONE server instance stays token-identical
    across consecutive runs — including the gemma3 sliding-window ring
    (blocks freed on release/preemption are reused immediately by the
    next tenant with no scrub)."""
    cfg, params = _setup(arch)
    rng = np.random.RandomState(11)
    kw = dict(slots=2, max_prompt=16, prefill_chunk=4, max_new_tokens=12,
              block_size=8, pool_blocks=6, prefix_cache=False)
    srv = PagedBatchServer(cfg, params, precision=precision, **kw)
    oracle = (PagedBatchServer(cfg, params, precision="int8_fakequant",
                               **kw) if precision == "int8" else None)
    total_preempt = 0
    for wave in range(3):                 # three waves over the same pool
        lens = [14, 15, 13]
        budgets = [12, 11, 12]
        prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
                   for n in lens]
        reqs = srv.submit(prompts, max_new_tokens=budgets)
        m = srv.run()
        total_preempt += m["preemptions"]
        if oracle is None:
            refs = [_reference_decode(cfg, params, p, b)
                    for p, b in zip(prompts, budgets)]
        else:
            oreqs = oracle.submit(prompts, max_new_tokens=budgets)
            oracle.run()
            refs = [r.tokens for r in oreqs]
        assert [r.tokens for r in reqs] == refs, \
            f"{arch}/{precision} wave {wave} diverged"
        # every wave drains: all blocks return to the pool
        assert srv.manager.free_blocks == srv.pool_blocks
    assert total_preempt > 0, "churn never forced a preemption"


# ---------------------------------------------------------------------------
# AOT artifact + layout plumbing
# ---------------------------------------------------------------------------
def test_paged_artifact_signature_and_report():
    """The paged decode artifact takes (params, cache, token, position,
    kv_len, block_table) and prices the pool per block."""
    cfg, params = _setup("internlm2-1.8b")
    srv = PagedBatchServer(cfg, params, slots=2, max_prompt=16,
                           prefill_chunk=4, max_new_tokens=4,
                           block_size=8, use_artifact=True)
    rng = np.random.RandomState(2)
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (5, 9)]
    reqs = srv.submit(prompts, max_new_tokens=[3, 4])
    m = srv.run()
    assert m["artifact_bytes"] > 0
    mem = srv.artifact.memory
    assert mem["kv_pool_blocks"] == srv.pool_blocks
    assert mem["kv_block_bytes"] == kv_pool_block_bytes(
        cfg, srv.capacity, srv.prec, srv.block_size)
    for r, p, b in zip(reqs, prompts, (3, 4)):
        assert r.tokens == _reference_decode(cfg, params, p, b)


def test_paged_cache_layout_per_family():
    """Pool leaves replace exactly the full-attention rectangles; ring /
    SSM leaves keep their slot shapes; pure-SSM pages nothing."""
    for arch, keys in (("internlm2-1.8b", ("k", "v")),
                       ("gemma3-4b", ("global_k", "global_v")),
                       ("zamba2-2.7b", ("attn_k", "attn_v")),
                       ("falcon-mamba-7b", ())):
        cfg, _ = _setup(arch)
        assert paged_cache_keys(cfg) == keys, arch
        cache = abstract_paged_cache(cfg, slots=2, capacity=64,
                                     num_blocks=5, block_size=8)
        for k in keys:
            leaf = cache[k]
            arr = leaf.q if hasattr(leaf, "q") else leaf
            assert arr.shape[-4:-2] == (5, 8), (arch, k, arr.shape)
        if keys:
            assert cache["pool_pos"].shape == (5, 8)
            assert "full_pos" not in cache
        if arch == "gemma3-4b":
            # ring leaves stay slot-addressed at the window length
            assert cache["local_k"].shape[-4] == 2
            assert cache["local_pos"].shape[0] == 2
