"""Per-architecture smoke tests (reduced configs, CPU): one forward/train
step asserting output shapes + no NaNs, and decode-vs-prefill consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.arch import SHAPES, ShapeConfig, shape_applicable
from repro.models import api
from repro.models.params import init_params, param_count
from repro.models.transformer import grow_cache

ARCHS = list(configs.ALIASES)
TRAIN = ShapeConfig("smoke_train", seq_len=32, global_batch=2, kind="train")
PREFILL = ShapeConfig("smoke_prefill", seq_len=16, global_batch=2,
                      kind="prefill")


@pytest.fixture(scope="module")
def arch_setup():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = configs.get_smoke(arch)
            params = init_params(cfg, jax.random.key(0))
            cache[arch] = (cfg, params)
        return cache[arch]
    return get


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch, arch_setup):
    cfg, params = arch_setup(arch)
    inputs = api.synthetic_inputs(cfg, TRAIN, jax.random.key(1))
    loss, metrics = api.model_fns(cfg).forward_train(cfg, params, inputs)
    assert loss.shape == ()
    assert np.isfinite(float(loss)), arch
    # loss starts near ln(vocab) for random init
    assert 2.0 < float(loss) < 12.0, (arch, float(loss))


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_shapes(arch, arch_setup):
    cfg, params = arch_setup(arch)
    inputs = api.synthetic_inputs(cfg, PREFILL, jax.random.key(2))
    logits, cache = api.model_fns(cfg).forward_prefill(cfg, params, inputs)
    assert logits.shape == (2, cfg.padded_vocab())
    assert np.all(np.isfinite(np.asarray(logits, np.float32)))
    assert cache  # non-empty pytree


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_prefill_oracle(arch, arch_setup):
    """Decoding token S given a cache of [0, S) must equal prefilling
    S+1 tokens — the core serving-correctness invariant."""
    cfg, params = arch_setup(arch)
    fns = api.model_fns(cfg)
    inputs = api.synthetic_inputs(cfg, PREFILL, jax.random.key(3))
    s = PREFILL.seq_len
    _, cache = fns.forward_prefill(cfg, params, inputs)

    if cfg.is_encdec:
        cache = dict(cache)
        for kk in ("k", "v"):
            pad = [(0, 0)] * cache[kk].ndim
            pad[-3] = (0, 4)
            cache[kk] = jnp.pad(cache[kk], pad)
        cache["full_pos"] = jnp.pad(cache["full_pos"], ((0, 0), (0, 4)),
                                    constant_values=-1)
    else:
        cache = grow_cache(cfg, cache, 4)

    tok = jnp.array([5, 7], dtype=jnp.int32)
    pos = jnp.full((2,), s, jnp.int32)
    dlogits, _ = fns.forward_decode(cfg, params, cache, tok, pos)

    inputs2 = dict(inputs)
    if "tokens" in inputs2:
        inputs2["tokens"] = jnp.concatenate(
            [inputs["tokens"], tok[:, None]], axis=1)
    else:
        emb = jnp.take(params["embed"], tok, axis=0)[:, None, :] \
            .astype(cfg.activation_dtype)
        inputs2["embeddings"] = jnp.concatenate(
            [inputs["embeddings"], emb], axis=1)
        if "positions" in inputs2:
            extra = jnp.full((2, 1, 3), s, jnp.int32)
            inputs2["positions"] = jnp.concatenate(
                [inputs["positions"], extra], axis=1)
    ologits, _ = fns.forward_prefill(cfg, params, inputs2)
    err = float(jnp.max(jnp.abs(dlogits.astype(jnp.float32)
                                - ologits.astype(jnp.float32))))
    # bf16 SSM states accumulate small drift; exact for pure attention
    tol = 0.05 if cfg.family in ("ssm", "hybrid") else 1e-3
    assert err < tol, (arch, err)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_param_count(arch):
    """FULL configs build their spec tree and land in the advertised
    parameter-count ballpark (catches config typos)."""
    cfg = configs.get(arch)
    n = param_count(cfg)
    expected = {
        "internlm2-1.8b": 1.9e9, "granite-3-8b": 8.2e9, "gemma3-4b": 4.3e9,
        "llama3.2-3b": 3.2e9, "seamless-m4t-large-v2": 2.3e9,
        "dbrx-132b": 132e9, "phi3.5-moe-42b-a6.6b": 42e9,
        "zamba2-2.7b": 2.7e9, "falcon-mamba-7b": 7.3e9,
        "qwen2-vl-72b": 72e9,
    }[arch]
    assert 0.55 * expected < n < 1.7 * expected, (arch, n, expected)


def test_long_500k_policy():
    """Sub-quadratic gate matches DESIGN.md (3 run, 7 skip)."""
    runs = []
    for arch in ARCHS:
        ok, _ = shape_applicable(configs.get(arch), SHAPES["long_500k"])
        if ok:
            runs.append(arch)
    assert sorted(runs) == ["falcon-mamba-7b", "gemma3-4b", "zamba2-2.7b"]


def test_mrope_vs_rope_equivalence_on_text():
    """M-RoPE with identical (t,h,w) position streams == plain RoPE when
    sections tile the full head dim with the same positions."""
    from repro.models.layers import apply_mrope, apply_rope
    b, s, h, d = 1, 8, 2, 16
    x = jax.random.normal(jax.random.key(0), (b, s, h, d))
    pos = jnp.arange(s, dtype=jnp.int32)[None, :]
    pos3 = jnp.broadcast_to(pos[..., None], (b, s, 3))
    out_m = apply_mrope(x, pos3, 10000.0, (2, 3, 3))
    out_r = apply_rope(x, pos, 10000.0)
    np.testing.assert_allclose(np.asarray(out_m), np.asarray(out_r),
                               atol=1e-5)
