"""Continuous-batching serving engine tests (paper §4.6).

Covers the scheduler invariants the engine is built on: slot recycling
admits queued work before the batch drains, per-request budgets are
honored in-step, chunked pad-free prefill is token-exact versus an
unpadded no-batching reference decode, over-capacity prompts error
explicitly (never silently truncate), and metrics are sane.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.models.params import init_params
from repro.models.transformer import grow_cache
from repro.serve.kvcache import (alloc_decode_cache, put_slot,
                                 release_slot, slot_batch_axes, take_slot)
from repro.serve.scheduler import Slot, SlotScheduler
from repro.serve.server import (ContinuousBatchServer, StaticBatchServer,
                                _chunk_rows)

ARCH = "internlm2-1.8b"


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke(ARCH)
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


def _reference_decode(cfg, params, prompt, max_new):
    """No-batching oracle: exact-length prefill + contiguous decode."""
    fns = api.model_fns(cfg)
    logits, cache = fns.forward_prefill(
        cfg, params, {"tokens": jnp.asarray(prompt[None, :])})
    cache = grow_cache(cfg, cache, max_new + 1)
    out = [int(jnp.argmax(logits, -1)[0])]
    pos = len(prompt)
    for _ in range(max_new - 1):
        logits, cache = fns.forward_decode(
            cfg, params, cache, jnp.asarray([out[-1]], jnp.int32),
            jnp.asarray([pos], jnp.int32))
        out.append(int(jnp.argmax(logits, -1)[0]))
        pos += 1
    return out


# ---------------------------------------------------------------------------
# Scheduler units (host-side, no model)
# ---------------------------------------------------------------------------
def test_slot_lifecycle():
    """FREE → PREFILLING → ACTIVE → FREE, with the pad-free invariant
    write_idx == position == prompt_len at decode start."""
    s = Slot(0)
    assert s.free and not s.prefilling and not s.active
    s.occupy(rid=7, prompt=np.arange(11, dtype=np.int32), max_new=4)
    assert s.prefilling and not s.active and not s.free
    s.chunk_pos = 11
    s.begin_decode()
    assert s.active and not s.prefilling
    assert s.position == 11 and s.write_idx == 11 and s.generated == 1
    s.advance()
    assert s.position == 12 and s.write_idx == 12
    s.release()
    assert s.free and s.prompt is None


def test_slot_scheduler_fcfs():
    s = SlotScheduler(2)
    s.enqueue("a"), s.enqueue("b"), s.enqueue("c")
    adm = s.admissions()
    assert [r for _, r in adm] == ["a", "b"]
    for slot, _ in adm:
        slot.occupy(rid=1, prompt=np.arange(4, dtype=np.int32), max_new=4)
    assert s.admissions() == []      # no free slot for "c"
    adm[0][0].release()
    assert [r for _, r in s.admissions()] == ["c"]


def test_chunk_rows():
    assert _chunk_rows(8, 8) == 8
    assert _chunk_rows(9, 8) == 16
    assert _chunk_rows(1, 8) == 8
    assert _chunk_rows(16, 4) == 16


def test_over_capacity_prompt_errors(setup):
    """No silent truncation: a prompt that cannot fit a slot errors at
    submit (the old bucket policy kept the most recent tokens and
    silently dropped the rest)."""
    cfg, params = setup
    srv = ContinuousBatchServer(cfg, params, slots=1, max_prompt=16,
                                max_new_tokens=8)
    rng = np.random.RandomState(0)
    with pytest.raises(ValueError, match="cache rows"):
        srv.submit([rng.randint(0, cfg.vocab_size, 200).astype(np.int32)])
    with pytest.raises(ValueError, match="empty"):
        srv.submit([np.zeros((0,), np.int32)])
    # submit is atomic: a rejected batch registers nothing, even when
    # earlier prompts in it were fine
    ok = rng.randint(0, cfg.vocab_size, 5).astype(np.int32)
    bad = rng.randint(0, cfg.vocab_size, 200).astype(np.int32)
    with pytest.raises(ValueError):
        srv.submit([ok, bad])
    assert srv.requests == {} and not srv.sched.waiting


# ---------------------------------------------------------------------------
# Engine behavior
# ---------------------------------------------------------------------------
def test_slot_recycling_admits_before_drain(setup):
    """A queued request must be admitted into a freed slot while another
    request is still decoding — the continuous-batching invariant."""
    cfg, params = setup
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(3)]
    srv = ContinuousBatchServer(cfg, params, slots=2, max_prompt=8,
                                prefill_chunk=8, max_new_tokens=12)
    # slot 0 finishes early (2 tokens), slot 1 runs long (12); request 3
    # must start before request 2 finishes.
    r1, r2, r3 = srv.submit(prompts, max_new_tokens=[2, 12, 6])
    srv.run()
    assert r1.finished_step is not None and r2.finished_step is not None
    assert r3.admitted_step is not None
    assert r3.admitted_step < r2.finished_step, \
        "queued request waited for the whole batch (static behavior)"
    # and it actually decoded to completion
    assert len(r3.tokens) == 6


def test_per_request_max_new_honored(setup):
    cfg, params = setup
    rng = np.random.RandomState(1)
    budgets = [1, 3, 7, 5]
    prompts = [rng.randint(0, cfg.vocab_size, 5).astype(np.int32)
               for _ in budgets]
    srv = ContinuousBatchServer(cfg, params, slots=2, max_prompt=8,
                                max_new_tokens=8)
    reqs = srv.submit(prompts, max_new_tokens=budgets)
    m = srv.run()
    assert [len(r.tokens) for r in reqs] == budgets
    assert m["tokens_generated"] == sum(budgets)


def test_chunked_prefill_matches_reference(setup):
    """Chunked pad-free prefill + slot decode must be token-exact vs an
    unpadded single-request decode (no pad row ever enters the cache)."""
    cfg, params = setup
    rng = np.random.RandomState(2)
    lens = [3, 11, 7, 16]
    budgets = [5, 4, 6, 3]
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    srv = ContinuousBatchServer(cfg, params, slots=2, max_prompt=16,
                                prefill_chunk=4, max_new_tokens=8)
    reqs = srv.submit(prompts, max_new_tokens=budgets)
    srv.run()
    for r, p, b in zip(reqs, prompts, budgets):
        assert r.tokens == _reference_decode(cfg, params, p, b), \
            f"rid {r.rid}: chunked serve diverged from reference"


def test_static_and_continuous_agree(setup):
    """Scheduling must not change the tokens, only the latency."""
    cfg, params = setup
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (4, 9, 12, 6)]
    budgets = [3, 6, 2, 5]
    stat = StaticBatchServer(cfg, params, batch_size=2, max_prompt=16,
                             max_new_tokens=8)
    sreqs = stat.submit(prompts, max_new_tokens=budgets)
    stat.run()
    cont = ContinuousBatchServer(cfg, params, slots=2, max_prompt=16,
                                 max_new_tokens=8)
    creqs = cont.submit(prompts, max_new_tokens=budgets)
    cont.run()
    assert [r.tokens for r in sreqs] == [r.tokens for r in creqs]


def test_metrics_sanity(setup):
    cfg, params = setup
    rng = np.random.RandomState(4)
    prompts = [rng.randint(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(4)]
    srv = ContinuousBatchServer(cfg, params, slots=2, max_prompt=8,
                                max_new_tokens=4)
    reqs = srv.submit(prompts)
    m = srv.run()
    assert m["requests"] == 4
    assert m["tokens_per_s"] > 0
    assert m["tokens_generated"] == 16
    assert 0 < m["ttft_p50_s"] <= m["ttft_p95_s"]
    assert 0 < m["slot_utilization"] <= 1.0
    assert m["prefill_chunks"] >= 4       # ≥ one chunk per request
    # pad-free: the measured fill can never exceed what live tokens
    # occupy (pads used to inflate this)
    assert 0 < m["kv_fill_frac"] <= 1.0
    # TTFT ordering: requests admitted later can't have earlier first
    # tokens (FCFS admission, monotone clock)
    firsts = [r.first_token_at for r in reqs]
    assert firsts == sorted(firsts)


def test_slot_view_isolated(setup):
    """take_slot/put_slot touch exactly one row; release_slot
    invalidates it (positions only — K/V bytes stay unreachable)."""
    cfg, params = setup
    axes = slot_batch_axes(cfg, 3, 12)
    cache = alloc_decode_cache(cfg, slots=3, capacity=12)
    assert np.all(np.asarray(cache["full_pos"]) == -1)
    # run one chunk into slot 1's view and splice it back
    fns = api.model_fns(cfg)
    small = take_slot(cache, axes, 1)
    toks = jnp.asarray(np.arange(8, dtype=np.int32)[None, :])
    pos = jnp.asarray(np.arange(8, dtype=np.int32)[None, :])
    _, small2 = fns.forward_prefill_chunk(cfg, params, small, toks, pos)
    cache2 = put_slot(cache, small2, axes, 1)
    fp = np.asarray(cache2["full_pos"])
    assert np.all(fp[[0, 2]] == -1), "neighbor rows disturbed"
    assert list(fp[1][:8]) == list(range(8))
    assert np.all(fp[1][8:] == -1)
    k2, k0 = np.asarray(cache2["k"]), np.asarray(cache["k"])
    assert np.allclose(k2[..., 0, :, :, :], k0[..., 0, :, :, :])
    assert not np.allclose(k2[..., 1, :8, :, :], 0)
    cache3 = release_slot(cache2, 1)
    assert np.all(np.asarray(cache3["full_pos"]) == -1)
    # K/V bytes intentionally stay — positions are the validity source
    assert np.allclose(np.asarray(cache3["k"]), k2)


def test_batchserver_alias_is_continuous():
    from repro.serve.server import BatchServer
    assert BatchServer is ContinuousBatchServer


def test_kv_cache_bytes_encdec_sizing():
    """Pin the enc-dec sizing formula: encoder layers hold NO decode
    cache (the encoder runs once; its output is the cross KV); the
    decoder holds self-attn KV over seq plus cross-attn KV over the
    subsampled encoder length.  Cross-checked against the leaf bytes of
    an actual prefill cache."""
    from repro.core.arch import ShapeConfig
    from repro.serve.kvcache import kv_cache_bytes

    cfg = configs.get("seamless-m4t-large-v2")
    b, s, db = 2, 1024, 2
    per_entry = 2 * b * cfg.n_kv_heads * cfg.resolved_head_dim * db
    expect = (cfg.n_layers * per_entry * s
              + cfg.n_layers * per_entry * (s // cfg.enc_seq_divisor))
    assert kv_cache_bytes(cfg, b, s, db) == expect

    # the abstract prefill cache's K/V leaves carry exactly those bytes
    smoke = configs.get_smoke("seamless-m4t-large-v2")
    b2, s2 = 2, 16
    cache = api.abstract_cache(
        smoke, ShapeConfig("sizing", seq_len=s2, global_batch=b2,
                           kind="prefill"))
    kv_bytes = sum(
        int(np.prod(cache[key].shape))
        * jnp.dtype(cache[key].dtype).itemsize
        for key in ("k", "v", "xk", "xv"))
    itemsize = jnp.dtype(cache["k"].dtype).itemsize
    assert kv_bytes == kv_cache_bytes(smoke, b2, s2, itemsize)


# ---------------------------------------------------------------------------
# Slot lifecycle: reset → chunked prefill → release → re-admit, float + int8
# ---------------------------------------------------------------------------
from repro.core import quantize as qz  # noqa: E402


def test_slot_view_isolated_int8(setup):
    """The int8 cache (Int8KV pairs) honors the same slot-view contract:
    one row written through a chunk, neighbors untouched, release
    invalidates positions while the paired q/scale bytes stay."""
    cfg, params = setup
    qparams = qz.quantize_model_params(params, qz.INT8)
    axes = slot_batch_axes(cfg, 3, 12, qz.INT8)
    cache = alloc_decode_cache(cfg, slots=3, capacity=12, policy=qz.INT8)
    assert isinstance(cache["k"], qz.Int8KV)
    fns = api.model_fns(cfg)
    small = take_slot(cache, axes, 1)
    toks = jnp.asarray(np.arange(8, dtype=np.int32)[None, :])
    pos = jnp.asarray(np.arange(8, dtype=np.int32)[None, :])
    _, small2 = fns.forward_prefill_chunk(cfg, qparams, small, toks, pos,
                                          policy=qz.INT8)
    assert isinstance(small2["k"], qz.Int8KV)
    cache2 = put_slot(cache, small2, axes, 1)
    fp = np.asarray(cache2["full_pos"])
    assert np.all(fp[[0, 2]] == -1), "neighbor rows disturbed"
    assert list(fp[1][:8]) == list(range(8))
    q2, q0 = np.asarray(cache2["k"].q), np.asarray(cache["k"].q)
    s2 = np.asarray(cache2["k"].scale)
    assert np.array_equal(q2[..., 0, :, :, :], q0[..., 0, :, :, :])
    assert not np.array_equal(q2[..., 1, :8, :, :],
                              np.zeros_like(q2[..., 1, :8, :, :]))
    assert np.all(s2[..., 1, :8, :] > 0), "scales not written with values"
    cache3 = release_slot(cache2, 1)
    assert np.all(np.asarray(cache3["full_pos"]) == -1)
    assert np.array_equal(np.asarray(cache3["k"].q), q2)


@pytest.mark.parametrize("precision", ["float", "int8"])
def test_slot_reuse_after_release_exact(setup, precision):
    """A slot that went reset → chunked prefill → release must serve its
    next request exactly: stale KV from the previous occupant (bytes are
    kept, only positions are wiped) can never leak into attention."""
    cfg, params = setup
    if precision == "int8":
        import dataclasses
        cfg = dataclasses.replace(cfg, dtype="float32")
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (6, 9, 4)]
    budgets = [4, 3, 5]
    # one slot: every request reuses the same cache row sequentially
    srv = ContinuousBatchServer(cfg, params, slots=1, max_prompt=16,
                                prefill_chunk=4, max_new_tokens=8,
                                precision=precision)
    reqs = srv.submit(prompts, max_new_tokens=budgets)
    srv.run()
    if precision == "float":
        refs = [_reference_decode(cfg, params, p, b)
                for p, b in zip(prompts, budgets)]
    else:
        # fresh single-request int8 servers: no prior slot occupancy
        refs = []
        for p, b in zip(prompts, budgets):
            one = ContinuousBatchServer(cfg, params, slots=1, max_prompt=16,
                                        prefill_chunk=4, max_new_tokens=8,
                                        precision="int8")
            (r,) = one.submit([p], max_new_tokens=[b])
            one.run()
            refs.append(r.tokens)
    assert [r.tokens for r in reqs] == refs, \
        "slot reuse leaked state between requests"


# ---------------------------------------------------------------------------
# Sliding-window ring caches (local_global arch), float + int8
# ---------------------------------------------------------------------------
RING_ARCH = "gemma3-4b"


@pytest.fixture(scope="module")
def ring_setup():
    import dataclasses
    cfg = dataclasses.replace(configs.get_smoke(RING_ARCH), dtype="float32")
    params = init_params(cfg, jax.random.key(1))
    return cfg, params


def test_ring_prefill_quantizes_after_gather(ring_setup):
    """Int8 ring caches are the quantization of the float ring caches:
    per-entry quantization commutes with ``_ring_select``'s gather, so
    one code path covers contiguous and ring layouts."""
    cfg, params = ring_setup
    fns = api.model_fns(cfg)
    toks = jnp.asarray(np.arange(16, dtype=np.int32)[None, :])
    _, float_cache = fns.forward_prefill(cfg, params, {"tokens": toks})
    _, q_cache = fns.forward_prefill(cfg, params, {"tokens": toks}, qz.INT8)
    for key in ("local_k", "local_v", "tail_k", "global_k"):
        if key not in float_cache:
            continue
        expect = qz.quant_kv(float_cache[key])
        got = q_cache[key]
        assert isinstance(got, qz.Int8KV), key
        np.testing.assert_array_equal(np.asarray(got.q),
                                      np.asarray(expect.q), err_msg=key)
        np.testing.assert_array_equal(np.asarray(got.scale),
                                      np.asarray(expect.scale), err_msg=key)
    np.testing.assert_array_equal(np.asarray(q_cache["local_pos"]),
                                  np.asarray(float_cache["local_pos"]))


@pytest.mark.parametrize("precision", ["float", "int8"])
def test_ring_serving_token_exact(ring_setup, precision):
    """Continuous serving on a local:global sliding-window arch — ring
    caches filled by chunked scatter writes, ring-slot decode writes —
    is token-exact vs the contiguous reference (float) or the fake-quant
    float simulation (int8)."""
    cfg, params = ring_setup
    rng = np.random.RandomState(8)
    lens = [5, 12, 9]
    budgets = [4, 6, 3]
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    srv = ContinuousBatchServer(cfg, params, slots=2, max_prompt=16,
                                prefill_chunk=8, max_new_tokens=8,
                                precision=precision)
    reqs = srv.submit(prompts, max_new_tokens=budgets)
    srv.run()
    if precision == "float":
        refs = [_reference_decode(cfg, params, p, b)
                for p, b in zip(prompts, budgets)]
    else:
        fq = ContinuousBatchServer(cfg, params, slots=2, max_prompt=16,
                                   prefill_chunk=8, max_new_tokens=8,
                                   precision="int8_fakequant")
        fq.submit(prompts, max_new_tokens=budgets)
        fq.run()
        refs = [r.tokens for r in fq.requests.values()]
    assert [r.tokens for r in reqs] == refs
