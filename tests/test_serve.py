"""Continuous-batching serving engine tests (paper §4.6).

Covers the scheduler invariants the engine is built on: slot recycling
admits queued work before the batch drains, per-request budgets are
honored in-step, left-padded bucket prefill is token-exact versus an
unpadded no-batching reference decode, and metrics are sane.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.models.params import init_params
from repro.serve.kvcache import (alloc_decode_cache, grow_cache,
                                 release_slot, write_slot)
from repro.serve.scheduler import BucketPolicy, SlotScheduler
from repro.serve.server import (ContinuousBatchServer, StaticBatchServer,
                                _left_pad)

ARCH = "internlm2-1.8b"


@pytest.fixture(scope="module")
def setup():
    cfg = configs.get_smoke(ARCH)
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


def _reference_decode(cfg, params, prompt, max_new):
    """No-batching oracle: exact-length prefill + contiguous decode."""
    fns = api.model_fns(cfg)
    logits, cache = fns.forward_prefill(
        cfg, params, {"tokens": jnp.asarray(prompt[None, :])})
    cache = grow_cache(cfg, cache, max_new + 1)
    out = [int(jnp.argmax(logits, -1)[0])]
    pos = len(prompt)
    for _ in range(max_new - 1):
        logits, cache = fns.forward_decode(
            cfg, params, cache, jnp.asarray([out[-1]], jnp.int32),
            jnp.asarray([pos], jnp.int32))
        out.append(int(jnp.argmax(logits, -1)[0]))
        pos += 1
    return out


# ---------------------------------------------------------------------------
# Scheduler / bucket units (host-side, no model)
# ---------------------------------------------------------------------------
def test_bucket_policy():
    p = BucketPolicy((32, 8, 16))
    assert p.buckets == (8, 16, 32)
    assert p.bucket_for(1) == 8
    assert p.bucket_for(8) == 8
    assert p.bucket_for(9) == 16
    assert p.bucket_for(999) == 32   # truncation bucket


def test_slot_scheduler_fcfs():
    s = SlotScheduler(2)
    s.enqueue("a"), s.enqueue("b"), s.enqueue("c")
    adm = s.admissions()
    assert [r for _, r in adm] == ["a", "b"]
    for slot, _ in adm:
        slot.occupy(rid=1, prompt_len=4, bucket=8, max_new=4)
    assert s.admissions() == []      # no free slot for "c"
    adm[0][0].release()
    assert [r for _, r in s.admissions()] == ["c"]


def test_left_pad_positions():
    tokens, positions, plen = _left_pad(np.array([7, 8, 9], np.int32), 6)
    assert plen == 3
    assert list(tokens) == [0, 0, 0, 7, 8, 9]
    assert list(positions) == [-1, -1, -1, 0, 1, 2]


# ---------------------------------------------------------------------------
# Engine behavior
# ---------------------------------------------------------------------------
def test_slot_recycling_admits_before_drain(setup):
    """A queued request must be admitted into a freed slot while another
    request is still decoding — the continuous-batching invariant."""
    cfg, params = setup
    rng = np.random.RandomState(0)
    prompts = [rng.randint(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(3)]
    srv = ContinuousBatchServer(cfg, params, slots=2, buckets=(8,),
                                max_new_tokens=12)
    # slot 0 finishes early (2 tokens), slot 1 runs long (12); request 3
    # must start before request 2 finishes.
    r1, r2, r3 = srv.submit(prompts, max_new_tokens=[2, 12, 6])
    srv.run()
    assert r1.finished_step is not None and r2.finished_step is not None
    assert r3.admitted_step is not None
    assert r3.admitted_step < r2.finished_step, \
        "queued request waited for the whole batch (static behavior)"
    # and it actually decoded to completion
    assert len(r3.tokens) == 6


def test_per_request_max_new_honored(setup):
    cfg, params = setup
    rng = np.random.RandomState(1)
    budgets = [1, 3, 7, 5]
    prompts = [rng.randint(0, cfg.vocab_size, 5).astype(np.int32)
               for _ in budgets]
    srv = ContinuousBatchServer(cfg, params, slots=2, buckets=(8,),
                                max_new_tokens=8)
    reqs = srv.submit(prompts, max_new_tokens=budgets)
    m = srv.run()
    assert [len(r.tokens) for r in reqs] == budgets
    assert m["tokens_generated"] == sum(budgets)


def test_leftpad_prefill_matches_reference(setup):
    """Bucketed left-pad prefill + slot decode must be token-exact vs an
    unpadded single-request decode (attention masks reject pos −1)."""
    cfg, params = setup
    rng = np.random.RandomState(2)
    lens = [3, 11, 7, 16]
    budgets = [5, 4, 6, 3]
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    srv = ContinuousBatchServer(cfg, params, slots=2, buckets=(4, 8, 16),
                                max_new_tokens=8)
    reqs = srv.submit(prompts, max_new_tokens=budgets)
    srv.run()
    for r, p, b in zip(reqs, prompts, budgets):
        assert r.tokens == _reference_decode(cfg, params, p, b), \
            f"rid {r.rid}: padded serve diverged from reference"


def test_static_and_continuous_agree(setup):
    """Scheduling must not change the tokens, only the latency."""
    cfg, params = setup
    rng = np.random.RandomState(3)
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (4, 9, 12, 6)]
    budgets = [3, 6, 2, 5]
    stat = StaticBatchServer(cfg, params, batch_size=2, prompt_len=16,
                             max_new_tokens=8)
    sreqs = stat.submit(prompts, max_new_tokens=budgets)
    stat.run()
    cont = ContinuousBatchServer(cfg, params, slots=2, buckets=(16,),
                                 max_new_tokens=8)
    creqs = cont.submit(prompts, max_new_tokens=budgets)
    cont.run()
    assert [r.tokens for r in sreqs] == [r.tokens for r in creqs]


def test_metrics_sanity(setup):
    cfg, params = setup
    rng = np.random.RandomState(4)
    prompts = [rng.randint(0, cfg.vocab_size, 6).astype(np.int32)
               for _ in range(4)]
    srv = ContinuousBatchServer(cfg, params, slots=2, buckets=(8,),
                                max_new_tokens=4)
    reqs = srv.submit(prompts)
    m = srv.run()
    assert m["requests"] == 4
    assert m["tokens_per_s"] > 0
    assert m["tokens_generated"] == 16
    assert 0 < m["ttft_p50_s"] <= m["ttft_p95_s"]
    assert 0 < m["slot_utilization"] <= 1.0
    # TTFT ordering: requests admitted later can't have earlier first
    # tokens (FCFS admission, monotone clock)
    firsts = [r.first_token_at for r in reqs]
    assert firsts == sorted(firsts)


def test_slot_cache_write_release_isolated(setup):
    """write_slot touches exactly one row; release_slot invalidates it."""
    cfg, params = setup
    cache = alloc_decode_cache(cfg, slots=3, capacity=12)
    assert np.all(np.asarray(cache["full_pos"]) == -1)
    fns = api.model_fns(cfg)
    toks = jnp.asarray(np.arange(8, dtype=np.int32)[None, :])
    _, small = fns.forward_prefill(cfg, params, {"tokens": toks})
    cache2 = write_slot(cache, small, 1)
    fp = np.asarray(cache2["full_pos"])
    assert np.all(fp[[0, 2]] == -1), "neighbor rows disturbed"
    assert list(fp[1][:8]) == list(range(8))
    assert np.all(fp[1][8:] == -1)
    k2, k0 = np.asarray(cache2["k"]), np.asarray(cache["k"])
    assert np.allclose(k2[..., 0, :, :, :], k0[..., 0, :, :, :])
    assert not np.allclose(k2[..., 1, :8, :, :], 0)
    cache3 = release_slot(cache2, 1)
    assert np.all(np.asarray(cache3["full_pos"]) == -1)
    # K/V bytes intentionally stay — positions are the validity source
    assert np.allclose(np.asarray(cache3["k"]), k2)


def test_batchserver_alias_is_continuous():
    from repro.serve.server import BatchServer
    assert BatchServer is ContinuousBatchServer


def test_kv_cache_bytes_encdec_sizing():
    """Pin the enc-dec sizing formula: encoder layers hold NO decode
    cache (the encoder runs once; its output is the cross KV); the
    decoder holds self-attn KV over seq plus cross-attn KV over the
    subsampled encoder length.  Cross-checked against the leaf bytes of
    an actual prefill cache."""
    from repro.core.arch import ShapeConfig
    from repro.serve.kvcache import kv_cache_bytes

    cfg = configs.get("seamless-m4t-large-v2")
    b, s, db = 2, 1024, 2
    per_entry = 2 * b * cfg.n_kv_heads * cfg.resolved_head_dim * db
    expect = (cfg.n_layers * per_entry * s
              + cfg.n_layers * per_entry * (s // cfg.enc_seq_divisor))
    assert kv_cache_bytes(cfg, b, s, db) == expect

    # the abstract prefill cache's K/V leaves carry exactly those bytes
    smoke = configs.get_smoke("seamless-m4t-large-v2")
    b2, s2 = 2, 16
    cache = api.abstract_cache(
        smoke, ShapeConfig("sizing", seq_len=s2, global_batch=b2,
                           kind="prefill"))
    kv_bytes = sum(
        int(np.prod(cache[key].shape))
        * jnp.dtype(cache[key].dtype).itemsize
        for key in ("k", "v", "xk", "xv"))
    itemsize = jnp.dtype(cache["k"].dtype).itemsize
    assert kv_bytes == kv_cache_bytes(smoke, b2, s2, itemsize)


# ---------------------------------------------------------------------------
# Slot lifecycle: alloc → write → release → re-admit, float and int8
# ---------------------------------------------------------------------------
from repro.core import quantize as qz  # noqa: E402


def test_slot_cache_write_release_isolated_int8(setup):
    """The int8 cache (Int8KV pairs) honors the same slot API contract:
    one row spliced, neighbors untouched, release invalidates positions
    while the paired q/scale bytes stay."""
    cfg, params = setup
    cache = alloc_decode_cache(cfg, slots=3, capacity=12, policy=qz.INT8)
    assert isinstance(cache["k"], qz.Int8KV)
    fns = api.model_fns(cfg)
    toks = jnp.asarray(np.arange(8, dtype=np.int32)[None, :])
    _, small = fns.forward_prefill(cfg, params, {"tokens": toks}, qz.INT8)
    assert isinstance(small["k"], qz.Int8KV)
    cache2 = write_slot(cache, small, 1)
    fp = np.asarray(cache2["full_pos"])
    assert np.all(fp[[0, 2]] == -1), "neighbor rows disturbed"
    assert list(fp[1][:8]) == list(range(8))
    q2, q0 = np.asarray(cache2["k"].q), np.asarray(cache["k"].q)
    s2 = np.asarray(cache2["k"].scale)
    assert np.array_equal(q2[..., 0, :, :, :], q0[..., 0, :, :, :])
    assert not np.array_equal(q2[..., 1, :8, :, :],
                              np.zeros_like(q2[..., 1, :8, :, :]))
    assert np.all(s2[..., 1, :8, :] > 0), "scales not spliced with values"
    cache3 = release_slot(cache2, 1)
    assert np.all(np.asarray(cache3["full_pos"]) == -1)
    assert np.array_equal(np.asarray(cache3["k"].q), q2)


@pytest.mark.parametrize("precision", ["float", "int8"])
def test_slot_reuse_after_release_exact(setup, precision):
    """A slot that went alloc → write → release must serve its next
    request exactly: stale KV from the previous occupant (bytes are kept,
    only positions are wiped) can never leak into attention."""
    cfg, params = setup
    if precision == "int8":
        import dataclasses
        cfg = dataclasses.replace(cfg, dtype="float32")
    rng = np.random.RandomState(7)
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in (6, 9, 4)]
    budgets = [4, 3, 5]
    # one slot: every request reuses the same cache row sequentially
    srv = ContinuousBatchServer(cfg, params, slots=1, buckets=(16,),
                                max_new_tokens=8, precision=precision)
    reqs = srv.submit(prompts, max_new_tokens=budgets)
    srv.run()
    if precision == "float":
        refs = [_reference_decode(cfg, params, p, b)
                for p, b in zip(prompts, budgets)]
    else:
        # fresh single-request int8 servers: no prior slot occupancy
        refs = []
        for p, b in zip(prompts, budgets):
            one = ContinuousBatchServer(cfg, params, slots=1, buckets=(16,),
                                        max_new_tokens=8, precision="int8")
            (r,) = one.submit([p], max_new_tokens=[b])
            one.run()
            refs.append(r.tokens)
    assert [r.tokens for r in reqs] == refs, \
        "slot reuse leaked state between requests"


# ---------------------------------------------------------------------------
# Sliding-window ring reconstruction (local_global arch), float + int8
# ---------------------------------------------------------------------------
RING_ARCH = "gemma3-4b"


@pytest.fixture(scope="module")
def ring_setup():
    import dataclasses
    cfg = dataclasses.replace(configs.get_smoke(RING_ARCH), dtype="float32")
    params = init_params(cfg, jax.random.key(1))
    return cfg, params


def test_ring_prefill_quantizes_after_gather(ring_setup):
    """Int8 ring caches are the quantization of the float ring caches:
    per-entry quantization commutes with ``_ring_select``'s gather, so
    one code path covers contiguous and ring layouts."""
    cfg, params = ring_setup
    fns = api.model_fns(cfg)
    toks = jnp.asarray(np.arange(16, dtype=np.int32)[None, :])
    _, float_cache = fns.forward_prefill(cfg, params, {"tokens": toks})
    _, q_cache = fns.forward_prefill(cfg, params, {"tokens": toks}, qz.INT8)
    for key in ("local_k", "local_v", "tail_k", "global_k"):
        if key not in float_cache:
            continue
        expect = qz.quant_kv(float_cache[key])
        got = q_cache[key]
        assert isinstance(got, qz.Int8KV), key
        np.testing.assert_array_equal(np.asarray(got.q),
                                      np.asarray(expect.q), err_msg=key)
        np.testing.assert_array_equal(np.asarray(got.scale),
                                      np.asarray(expect.scale), err_msg=key)
    np.testing.assert_array_equal(np.asarray(q_cache["local_pos"]),
                                  np.asarray(float_cache["local_pos"]))


@pytest.mark.parametrize("precision", ["float", "int8"])
def test_ring_serving_token_exact(ring_setup, precision):
    """Continuous serving on a local:global sliding-window arch — ring
    caches rebuilt from left-padded bucket prefills, ring-slot decode
    writes — is token-exact vs the contiguous reference (float) or the
    fake-quant float simulation (int8)."""
    cfg, params = ring_setup
    rng = np.random.RandomState(8)
    lens = [5, 12, 9]
    budgets = [4, 6, 3]
    prompts = [rng.randint(0, cfg.vocab_size, n).astype(np.int32)
               for n in lens]
    srv = ContinuousBatchServer(cfg, params, slots=2, buckets=(8, 16),
                                max_new_tokens=8, precision=precision)
    reqs = srv.submit(prompts, max_new_tokens=budgets)
    srv.run()
    if precision == "float":
        refs = [_reference_decode(cfg, params, p, b)
                for p, b in zip(prompts, budgets)]
    else:
        fq = ContinuousBatchServer(cfg, params, slots=2, buckets=(8, 16),
                                   max_new_tokens=8,
                                   precision="int8_fakequant")
        fq.submit(prompts, max_new_tokens=budgets)
        fq.run()
        refs = [r.tokens for r in fq.requests.values()]
    assert [r.tokens for r in reqs] == refs
