"""Data substrate: ingestion, versioned dataset invariants, pipeline."""
import io
import json
import wave

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data import ingest
from repro.data.dataset import Dataset, Sample, split_of
from repro.data.pipeline import BatchPipeline, Prefetcher
from repro.data.synthetic import keyword_audio, token_stream


def test_ingest_csv():
    s = ingest.ingest_csv(b"1.0,2.0\n3.0,4.0\n", label=1)
    assert s.data.shape == (2, 2)
    assert s.label == 1


def test_ingest_json():
    payload = json.dumps({"values": [0.1, 0.2, 0.3], "label": 2,
                          "device": "nano"}).encode()
    s = ingest.ingest_json(payload)
    assert s.label == 2
    assert s.metadata["device"] == "nano"
    np.testing.assert_allclose(s.data, [0.1, 0.2, 0.3], atol=1e-6)


def test_ingest_wav_roundtrip():
    sig = (np.sin(np.linspace(0, 40, 1600)) * 2 ** 14).astype(np.int16)
    buf = io.BytesIO()
    with wave.open(buf, "wb") as w:
        w.setnchannels(1)
        w.setsampwidth(2)
        w.setframerate(16000)
        w.writeframes(sig.tobytes())
    s = ingest.ingest_wav(buf.getvalue(), label=0)
    assert s.metadata["sample_rate"] == 16000
    assert abs(s.data.max() - sig.max() / 2 ** 15) < 1e-3


def test_dataset_versioning(tmp_path):
    ds = Dataset(tmp_path)
    samples = keyword_audio(n_per_class=4, n_classes=2, n_samples=800)
    ds.add_many(samples)
    v1 = ds.commit("initial")
    removed = next(iter(ds.samples))
    ds.remove(removed)
    v2 = ds.commit("removed one")
    assert v1 != v2
    old = ds.checkout(v1)
    assert len(old) == len(samples)
    assert removed in old.samples
    new = ds.checkout(v2)
    assert removed not in new.samples


def test_split_stability_under_additions():
    """Adding samples never moves existing samples across splits."""
    samples = keyword_audio(n_per_class=10, n_classes=2, n_samples=500,
                            seed=0)
    before = {s.sample_id: split_of(s.sample_id) for s in samples}
    more = keyword_audio(n_per_class=10, n_classes=2, n_samples=500, seed=9)
    after = {s.sample_id: split_of(s.sample_id)
             for s in samples + more}
    for sid, sp in before.items():
        assert after[sid] == sp


@settings(max_examples=20, deadline=None)
@given(st.binary(min_size=8, max_size=64))
def test_split_of_deterministic_and_partitioned(blob):
    import hashlib
    sid = hashlib.sha1(blob).hexdigest()
    s1, s2 = split_of(sid), split_of(sid)
    assert s1 == s2
    assert s1 in ("train", "val", "test")


def test_pipeline_host_sharding():
    xs = np.arange(64)[:, None].astype(np.float32)
    ys = np.arange(64).astype(np.int32)
    got = []
    for host in range(4):
        p = BatchPipeline({"x": xs, "y": ys}, batch_size=16, shuffle=True,
                          seed=3, host_index=host, host_count=4)
        got.append([b["y"] for b in p.epoch(0)])
    # same step across hosts covers disjoint quarters of the same batch
    for step in range(len(got[0])):
        union = np.concatenate([got[h][step] for h in range(4)])
        assert len(set(union.tolist())) == 16


def test_prefetcher_preserves_order():
    it = iter([{"i": i} for i in range(10)])
    out = [b["i"] for b in Prefetcher(it, depth=3)]
    assert out == list(range(10))


def test_token_stream_is_learnable_structure():
    toks = token_stream(20000, 64, seed=0)
    # bigram structure: top-4 successors should cover most transitions
    from collections import Counter
    succ = {}
    for a, b in zip(toks[:-1], toks[1:]):
        succ.setdefault(int(a), Counter())[int(b)] += 1
    cover = np.mean([sum(c for _, c in cnt.most_common(4)) / sum(cnt.values())
                     for cnt in succ.values()])
    assert cover > 0.6, cover
