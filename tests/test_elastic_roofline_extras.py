"""Elastic restore round-trip, fused-roofline credit, pod estimator
adapter, quantized-impulse artifact parity."""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.checkpoint.checkpointer import Checkpointer
from repro.core.arch import SHAPES
from repro.core.estimator import pod_estimate_from_report
from repro.launch.elastic import build_mesh, elastic_restore, plan_rescale
from repro.models.params import init_params, logical_axes
from repro.roofline.hw import V5E
from repro.roofline.model import (RooflineReport, attention_score_traffic,
                                  fused_adjustment, model_flops)
from repro.sharding.policy import make_rules


def test_elastic_restore_cycle(tmp_path):
    """save → 'lose nodes' → restore resharded onto a smaller mesh."""
    cfg = configs.get_smoke("internlm2-1.8b")
    params = init_params(cfg, jax.random.key(0))
    ck = Checkpointer(tmp_path)
    ck.save(10, params)

    plan = plan_rescale({"data": 1, "model": 1}, 1)  # host-scale shrink
    mesh = build_mesh(plan.new_shape)
    rules = make_rules("tp")
    restored, _ = elastic_restore(ck, params, rules, logical_axes(cfg),
                                  mesh)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, restored)
    assert max(jax.tree.leaves(diffs)) == 0.0


def test_fused_credit_only_for_attention():
    ssm = configs.get("falcon-mamba-7b")
    dense = configs.get("internlm2-1.8b")
    shape = SHAPES["prefill_32k"]
    assert attention_score_traffic(ssm, shape, 256) == 0.0
    assert attention_score_traffic(dense, shape, 256) > 0.0
    # decode gets no credit (scores are negligible there)
    assert attention_score_traffic(dense, SHAPES["decode_32k"], 256) == 0.0
    # sliding-window arch gets less credit per layer than dense S^2
    gem = configs.get("gemma3-4b")
    full = gem.replace(sliding_window=0, local_global_ratio=0)
    assert (attention_score_traffic(gem, shape, 256)
            < attention_score_traffic(full, shape, 256))


def test_fused_adjustment_improves_memory_bound_cell():
    cfg = configs.get("internlm2-1.8b")
    shape = SHAPES["prefill_32k"]
    rep = RooflineReport(
        arch=cfg.name, shape=shape.name, mesh="16x16", n_chips=256,
        hlo_flops=0.197 * V5E.peak_flops_bf16,
        hlo_bytes=0, hlo_bytes_min=0.78 * V5E.hbm_bandwidth,
        collective_bytes=0.31 * V5E.ici_bandwidth,
        collective_detail={}, per_device_hbm=2 * 2**30,
        model_flops=model_flops(cfg, shape)).finalize()
    adj = fused_adjustment(cfg, shape, rep)
    assert adj["roofline_fraction_fused"] > rep.roofline_fraction
    assert adj["t_memory_min_fused_s"] < rep.t_memory_min


def test_pod_estimator_adapter():
    row = {"mesh": "16x16", "t_compute_s": 0.5, "t_memory_s": 2.0,
           "t_memory_min_s": 0.8, "t_collective_s": 0.3,
           "hbm_gib": 12.0, "fits_hbm": True}
    e = pod_estimate_from_report(row)
    assert e.fits
    assert abs(e.nn_latency_ms - 800.0) < 1e-6   # binding term = mem lower
    assert "tpu-v5e-pod" in e.target


def test_dryrun_matrix_complete_on_disk():
    """The shipped dry-run matrix covers all 80 cells with no errors and
    the DESIGN.md skip policy."""
    import glob
    files = glob.glob("experiments/dryrun/*.json")
    if len(files) < 80:
        pytest.skip("dry-run matrix not generated in this environment")
    status = {}
    for f in files:
        d = json.load(open(f))
        status.setdefault(d["status"], 0)
        status[d["status"]] += 1
    assert status.get("error", 0) == 0
    assert status.get("skipped", 0) == 14          # 7 archs × 2 meshes
    assert status.get("ok", 0) == 66
