"""Shared test configuration.

Provides a deterministic fallback shim for ``hypothesis`` when the real
package is not installed (this container ships without it).  Property
tests then degrade to a fixed sweep of seeded examples instead of
breaking collection for the whole file.  The shim covers exactly the
subset the suite uses: ``@settings(max_examples=..., deadline=...)``,
``@given(...)`` over positional strategies, and ``st.integers`` /
``st.binary`` / ``st.floats``.
"""
import random
import sys
import types
import zlib


def _install_hypothesis_stub() -> None:
    mod = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

    def integers(min_value=0, max_value=2 ** 31 - 1):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def binary(min_size=0, max_size=64):
        def draw(rng):
            n = rng.randint(min_size, max_size)
            return bytes(rng.randrange(256) for _ in range(n))
        return _Strategy(draw)

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    st.integers, st.binary, st.floats = integers, binary, floats

    def settings(max_examples=10, **_kw):
        def deco(fn):
            fn._stub_max_examples = max_examples
            return fn
        return deco

    def given(*strategies):
        def deco(fn):
            # NOTE: no functools.wraps — pytest must see a zero-arg
            # signature, not the original one (drawn args would otherwise
            # be collected as fixtures).
            def runner():
                n = getattr(runner, "_stub_max_examples",
                            getattr(fn, "_stub_max_examples", 10))
                base = zlib.crc32(fn.__qualname__.encode())
                for i in range(n):
                    rng = random.Random(base + 0x9E3779B9 * i)
                    fn(*[s.draw(rng) for s in strategies])
            runner.__name__ = fn.__name__
            runner.__qualname__ = fn.__qualname__
            runner.__doc__ = fn.__doc__
            return runner
        return deco

    mod.given, mod.settings, mod.strategies = given, settings, st
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _install_hypothesis_stub()
