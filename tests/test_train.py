"""Training substrate: optimizer, grad accumulation, compression,
trainer fault tolerance, checkpoint round trips, elastic restore,
LR finder, serving loop."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro import configs
from repro.checkpoint.checkpointer import Checkpointer
from repro.core.arch import ShapeConfig
from repro.data.synthetic import lm_batches, token_stream
from repro.launch.elastic import plan_rescale
from repro.models import api
from repro.models.params import init_params
from repro.train import compression as comp
from repro.train.optimizer import AdamWConfig, adamw_init, adamw_update
from repro.train.schedule import lr_finder, warmup_cosine
from repro.train.train_step import make_train_step
from repro.train.trainer import Trainer, TrainerConfig


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = configs.get_smoke("internlm2-1.8b")
    params = init_params(cfg, jax.random.key(0))
    tokens = token_stream(50_000, cfg.vocab_size, seed=1)
    return cfg, params, tokens


def test_train_loss_decreases(tiny_setup, tmp_path):
    cfg, params, tokens = tiny_setup
    params = jax.tree.map(jnp.copy, params)   # donation-safe copy
    step = jax.jit(make_train_step(cfg, opt=AdamWConfig(lr=1e-3)),
                   donate_argnums=(0, 1))
    trainer = Trainer(step, params, adamw_init(params),
                      ckpt_dir=tmp_path / "ck",
                      config=TrainerConfig(total_steps=30, log_every=0,
                                           checkpoint_every=0))
    res = trainer.run(iter(lm_batches(tokens, 8, 32)))
    first = np.mean([h["loss"] for h in res["history"][:5]])
    last = np.mean([h["loss"] for h in res["history"][-5:]])
    assert last < first - 0.3, (first, last)


def test_grad_accumulation_equivalence(tiny_setup):
    """n_micro=4 must match n_micro=1 on the same global batch."""
    cfg, params, tokens = tiny_setup
    batch = next(lm_batches(tokens, 8, 32, seed=3))
    batch = {k: jnp.asarray(v) for k, v in batch.items()}
    opt = adamw_init(params)
    s1 = make_train_step(cfg, n_microbatch=1, remat="none")
    s4 = make_train_step(cfg, n_microbatch=4, remat="none")
    p1, _, m1 = jax.jit(s1)(params, opt, batch)
    p4, _, m4 = jax.jit(s4)(params, adamw_init(params), batch)
    assert abs(float(m1["loss"]) - float(m4["loss"])) < 2e-2
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        p1, p4)
    assert max(jax.tree.leaves(diffs)) < 5e-2


def test_remat_matches_no_remat(tiny_setup):
    cfg, params, tokens = tiny_setup
    batch = {k: jnp.asarray(v) for k, v in
             next(lm_batches(tokens, 4, 32, seed=5)).items()}
    from repro.models.api import model_fns
    fns = model_fns(cfg)
    g_plain = jax.grad(
        lambda p: fns.forward_train(cfg, p, batch, remat="none")[0])(params)
    g_remat = jax.grad(
        lambda p: fns.forward_train(cfg, p, batch, remat="full")[0])(params)
    diffs = jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a - b))), g_plain, g_remat)
    assert max(jax.tree.leaves(diffs)) < 1e-3


def test_gradient_compression_error_feedback():
    """int8-compressed SGD with error feedback tracks uncompressed SGD."""
    rng = np.random.RandomState(0)
    w_true = jnp.asarray(rng.randn(16), jnp.float32)
    x = jnp.asarray(rng.randn(256, 16), jnp.float32)
    y = x @ w_true

    def loss(w):
        return jnp.mean((x @ w - y) ** 2)

    w_ref = w_cmp = jnp.zeros(16)
    residual = {"g": jnp.zeros(16)}
    for _ in range(60):
        g_ref = jax.grad(loss)(w_ref)
        w_ref = w_ref - 0.05 * g_ref
        g = jax.grad(loss)(w_cmp)
        gc, residual = comp.compress_grads({"g": g}, residual, "int8")
        w_cmp = w_cmp - 0.05 * gc["g"]
    assert float(loss(w_cmp)) < 1e-2
    assert abs(float(loss(w_cmp)) - float(loss(w_ref))) < 1e-2


def test_topk_compression_sparsity():
    g = jnp.asarray(np.random.RandomState(0).randn(1000), jnp.float32)
    gc, _ = comp.compress_grads(
        {"g": g}, {"g": jnp.zeros(1000)}, "topk", topk_frac=0.05)
    nz = int(jnp.sum(gc["g"] != 0))
    assert nz <= 55


def test_trainer_crash_restart(tiny_setup, tmp_path):
    """Kill at step 25, resume from checkpoint, finish — the history
    continues from the restored step."""
    cfg, params, tokens = tiny_setup
    step = jax.jit(make_train_step(cfg, opt=AdamWConfig(lr=1e-3)))
    mk = lambda: Trainer(step, params, adamw_init(params),
                         ckpt_dir=tmp_path / "ck2",
                         config=TrainerConfig(total_steps=40,
                                              checkpoint_every=10,
                                              log_every=0,
                                              restore_best=False))
    t1 = mk()
    with pytest.raises(RuntimeError, match="injected node failure"):
        t1.run(iter(lm_batches(tokens, 4, 32)), fail_at=25)
    t2 = mk()
    assert t2.maybe_resume()
    assert t2.step == 20                      # last checkpoint before crash
    res = t2.run(iter(lm_batches(tokens, 4, 32)))
    assert t2.step == 40
    assert np.isfinite(res["final_loss"])


def test_checkpointer_atomicity(tmp_path):
    """A checkpoint without a manifest is invisible."""
    ck = Checkpointer(tmp_path)
    tree = {"a": jnp.arange(6.0), "b": {"c": jnp.ones((2, 3))}}
    ck.save(5, tree)
    # simulate a partial write: directory without manifest
    bad = tmp_path / "step_00000009"
    bad.mkdir()
    (bad / "a.npy").write_bytes(b"garbage")
    assert ck.latest_step() == 5
    restored, _ = ck.restore(tree)
    np.testing.assert_allclose(restored["a"], tree["a"])


def test_elastic_rescale_plan():
    plan = plan_rescale({"pod": 2, "data": 16, "model": 16}, 384)
    assert plan.new_shape["model"] == 16
    total = 1
    for v in plan.new_shape.values():
        total *= v
    assert total <= 384
    # model axis survives even a brutal shrink
    plan2 = plan_rescale({"data": 16, "model": 16}, 48)
    assert plan2.new_shape == {"data": 2, "model": 16}


def test_lr_finder_picks_reasonable_lr():
    """Quadratic bowl: finder must propose an lr that converges."""
    w0 = jnp.asarray([3.0])

    def probe(lr):
        w = w0
        for _ in range(5):
            w = w - lr * jax.grad(lambda v: jnp.sum(v ** 2))(w)
        return float(jnp.sum(w ** 2))

    lr, curve = lr_finder(probe, lr_min=1e-5, lr_max=10.0, n_probe=15)
    assert 1e-5 <= lr <= 1.1
    w = w0
    for _ in range(50):
        w = w - lr * jax.grad(lambda v: jnp.sum(v ** 2))(w)
    assert float(jnp.sum(w ** 2)) < 9.0


def test_warmup_cosine_shape():
    lrs = [float(warmup_cosine(s, base_lr=1.0, warmup=10, total=100))
           for s in range(100)]
    assert lrs[0] < lrs[9] <= 1.0
    assert max(lrs) <= 1.0 + 1e-6
    assert lrs[-1] < 0.2


def test_batch_server_generates(tiny_setup):
    from repro.serve.server import BatchServer
    cfg, params, _ = tiny_setup
    server = BatchServer(cfg, params, batch_size=2, prompt_len=8,
                         max_new_tokens=4)
    rng = np.random.RandomState(0)
    server.submit([rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
                   for _ in range(4)])
    m = server.run()
    assert m["requests"] == 4
    assert m["tokens_generated"] == 16
    assert m["tokens_per_s"] > 0
