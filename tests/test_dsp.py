"""DSP blocks + serving-side cache arithmetic."""
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro import configs
from repro.dsp import filterbank as fb
from repro.dsp.blocks import (MFCCBlock, MFEBlock, RawBlock,
                              SpectrogramBlock, frame_signal)
from repro.serve.kvcache import kv_cache_bytes


def test_frame_signal_shapes_and_content():
    x = jnp.arange(100, dtype=jnp.float32)[None]
    frames = frame_signal(x, frame_len=20, stride=10)
    assert frames.shape == (1, 9, 20)
    np.testing.assert_allclose(frames[0, 0], np.arange(20))
    np.testing.assert_allclose(frames[0, 1], np.arange(10, 30))


@pytest.mark.parametrize("block_cls,kw", [
    (MFEBlock, {"n_mels": 32}),
    (MFCCBlock, {"n_mels": 32, "n_coeffs": 10}),
    (SpectrogramBlock, {"n_fft": 256}),
])
def test_feature_shape_matches_output(block_cls, kw):
    blk = block_cls(**kw)
    n = 4000
    x = jnp.asarray(np.random.RandomState(0).randn(2, n), jnp.float32)
    out = blk(x)
    assert out.shape[1:] == blk.feature_shape(n)
    assert np.all(np.isfinite(np.asarray(out)))


def test_mfe_separates_frequencies():
    """A low tone and a high tone must land in different mel bins."""
    sr = 16000
    t = np.arange(sr) / sr
    lo = jnp.asarray(np.sin(2 * np.pi * 200 * t), jnp.float32)[None]
    hi = jnp.asarray(np.sin(2 * np.pi * 4000 * t), jnp.float32)[None]
    blk = MFEBlock(n_mels=40)
    e_lo = np.asarray(blk(lo)).mean(axis=1)[0]
    e_hi = np.asarray(blk(hi)).mean(axis=1)[0]
    assert e_lo.argmax() < e_hi.argmax()


def test_raw_block_normalizes():
    x = jnp.asarray(np.random.RandomState(0).randn(3, 500) * 7 + 3,
                    jnp.float32)
    out = np.asarray(RawBlock()(x))
    np.testing.assert_allclose(out.mean(axis=-1), 0, atol=1e-3)
    np.testing.assert_allclose(out.std(axis=-1), 1, atol=1e-2)


@settings(max_examples=10, deadline=None)
@given(st.integers(16, 48), st.integers(4, 12))
def test_mel_filterbank_partition(n_mels, seed):
    """Filters are non-negative and every filter has support."""
    m = fb.mel_filterbank(257, n_mels, 16000)
    assert (m >= 0).all()
    assert (m.sum(axis=0) > 0).all()


def test_dct_orthonormal():
    d = fb.dct_matrix(40, 40)
    np.testing.assert_allclose(d.T @ d, np.eye(40), atol=1e-4)


# ---------------------------------------------------------------------------
# kv cache arithmetic (serving substrate)
# ---------------------------------------------------------------------------
def test_kv_cache_bytes_orderings():
    dense = configs.get("internlm2-1.8b")
    ssm = configs.get("falcon-mamba-7b")
    swa = configs.get("gemma3-4b")
    b, s = 8, 32768
    # SSM cache is O(1) in seq; dense is O(S)
    assert kv_cache_bytes(ssm, b, s) == kv_cache_bytes(ssm, b, 2 * s)
    assert kv_cache_bytes(dense, b, 2 * s) > 1.9 * kv_cache_bytes(dense, b, s)
    # sliding-window arch caches far less than a dense arch of its size
    dense_like = swa.replace(sliding_window=0, local_global_ratio=0)
    assert kv_cache_bytes(swa, b, s) < 0.5 * kv_cache_bytes(dense_like, b, s)


def test_kv_cache_bytes_matches_dryrun_scale():
    """qwen2 decode_32k: analytic cache ~= the dry-run argument bytes."""
    cfg = configs.get("qwen2-vl-72b")
    total = kv_cache_bytes(cfg, 128, 32768)
    per_dev = total / 256
    # dry-run measured ~5.0 GiB/device of cache arguments
    assert 3 * 2**30 < per_dev < 8 * 2**30
