#!/usr/bin/env bash
# End-to-end smoke: the paper's quickstart loop + the serving benchmark
# in tiny mode, on both sides of the precision axis (paper C5: the same
# engine serves float and full-int8).  Finishes in a few minutes on CPU.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== quickstart (impulse train -> quantize -> estimate -> compile) ==="
python examples/quickstart.py

echo
echo "=== serve bench (static vs continuous batching, tiny, float) ==="
python benchmarks/serve_bench.py --tiny --precision float

echo
echo "=== serve bench (float vs int8 end-to-end, tiny) ==="
python benchmarks/serve_bench.py --tiny --precision int8

echo
echo "=== chunked-prefill serving (pad-free admission, float + int8) ==="
# Run the chunked pad-free admission path end-to-end through the Pallas
# interpreter (chunk-prefill + flash-decode kernels) on both sides of
# the precision axis: the chunk-size sweep exercises ragged final
# chunks, interleaved prefill/decode, and the kv_len fill metrics.
REPRO_KERNEL_PATH=interpret python benchmarks/serve_bench.py --tiny \
    --precision float --prefill-chunk 4 16
REPRO_KERNEL_PATH=interpret python benchmarks/serve_bench.py --tiny \
    --precision int8 --prefill-chunk 4

echo
echo "=== paged KV serving (block tables, prefix reuse, preemption) ==="
# Paged-pool engine end-to-end through the Pallas interpreter, float AND
# int8 in one run: a shared-prefix workload against a pool sized to
# force preempt-and-recompute (the summary line reports preemptions ≥ 1,
# prefix-hit rate, and live-KV HBM vs the contiguous rectangle);
# token-exactness vs the contiguous engine is asserted inside the bench.
REPRO_KERNEL_PATH=interpret python benchmarks/serve_bench.py \
    --requests 6 --slots 3 --max-prompt 24 --max-new 24 \
    --precision int8 --paged-only --pool-frac 0.34

echo
echo "=== decode-kernel parity (Pallas lowering via interpret mode) ==="
# Pin every kernels/ops dispatch to the Pallas interpreter so the
# flash-decode lowering is exercised on every smoke run, not just on TPU:
# kernel-vs-ref parity plus token-exact continuous serving through it.
REPRO_KERNEL_PATH=interpret python -m pytest -q tests/test_flash_decode.py
