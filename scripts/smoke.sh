#!/usr/bin/env bash
# End-to-end smoke: the paper's quickstart loop + the serving benchmark
# in tiny mode. Finishes in a few minutes on CPU.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

echo "=== quickstart (impulse train -> quantize -> estimate -> compile) ==="
python examples/quickstart.py

echo
echo "=== serve bench (static vs continuous batching, tiny) ==="
python benchmarks/serve_bench.py --tiny
