"""Paper Table 2: preprocessing + inference times, float vs int8, across
heterogeneous targets.

Two result families per (task × target × precision):
* ``est``  — the platform's static latency estimate (C2) for the MCU
  targets, with the Table-2 KWS-nano cells as the fit anchor and every
  other cell a *prediction*;
* ``cpu``  — measured µs on this host for the same impulse (DSP vs NN
  split), demonstrating the measurement path the platform pairs with
  estimates.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from benchmarks import common
from repro.core import estimator as est
from repro.core.quantize import fake_quant_params


def main() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    tasks = {
        "kws": common.trained_kws_impulse(),
        "vww": common.vww_impulse(),
        "ic": common.ic_impulse(),
    }
    for task, imp in tasks.items():
        # measured on this host
        if isinstance(imp.input_shape, int):
            raw = np.random.RandomState(0).randn(
                1, imp.input_shape).astype(np.float32)
        else:
            raw = np.random.RandomState(0).randn(
                1, *imp.input_shape).astype(np.float32)
        import jax
        dsp_us = common.time_call(jax.jit(imp.dsp.apply), raw)
        feats = imp.dsp.apply(raw)
        nn_us = common.time_call(
            jax.jit(lambda f: imp.learn.apply(imp.params, f)), feats)
        rows.append((f"table2/{task}/cpu/dsp", dsp_us, "measured"))
        rows.append((f"table2/{task}/cpu/nn_float", nn_us, "measured"))
        if imp.qparams is not None:
            fq = fake_quant_params(imp.qparams)
            nn8_us = common.time_call(
                jax.jit(lambda f: imp.learn.apply(fq, f)), feats)
            rows.append((f"table2/{task}/cpu/nn_int8", nn8_us,
                         "measured-fakequant"))
        # static estimates per MCU target
        for target in est.TARGETS:
            for int8 in (False, True):
                e = est.estimate_impulse(imp, target, engine="eon",
                                         int8=int8)
                tag = "int8" if int8 else "float"
                rows.append((
                    f"table2/{task}/{target}/{tag}/total",
                    e.total_latency_ms * 1e3,
                    f"dsp={e.dsp_latency_ms:.1f}ms nn="
                    f"{e.nn_latency_ms:.1f}ms fits={e.fits}"))
    common.emit(rows)
    return rows


if __name__ == "__main__":
    main()
