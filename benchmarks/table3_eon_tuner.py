"""Paper Table 3: the EON-Tuner-explored (DSP × NN) design space for KWS.

Runs the actual tuner (random sample → resource screen → short training)
and prints the Table-3 columns: preprocessing config, model, accuracy,
DSP/NN/total latency, RAM, flash.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from benchmarks import common
from repro.core.tuner import EONTuner


def main() -> List[Tuple[str, float, str]]:
    ds = common.kws_dataset()
    xtr, ytr = ds.arrays("train")
    xva, yva = ds.arrays("val")
    tuner = EONTuner(input_samples=common.KWS_SAMPLES, n_classes=4,
                     target="nano33ble", engine="eon", int8=False, seed=0)
    ranked = tuner.search((np.asarray(xtr), np.asarray(ytr)),
                          (np.asarray(xva), np.asarray(yva)),
                          n_samples=8, epochs=3)
    rows: List[Tuple[str, float, str]] = []
    for cand in ranked:
        e = cand.estimate
        rows.append((
            f"table3/{cand.describe().replace(',', ';').replace(' ', '')}",
            e.total_latency_ms * 1e3,
            f"acc={cand.accuracy:.2f} dsp={e.dsp_latency_ms:.0f}ms "
            f"nn={e.nn_latency_ms:.0f}ms ram={e.ram_kb:.0f}kB "
            f"flash={e.flash_kb:.0f}kB"))
    common.emit(rows)
    return rows


if __name__ == "__main__":
    main()
