"""Roofline table over the dry-run matrix (§Roofline source of truth).

Reads experiments/dryrun/*.json and prints every cell's three terms,
bottleneck, useful-flops ratio and roofline fraction.
"""
from __future__ import annotations

import glob
import json
from typing import List, Tuple

from benchmarks import common


def main(dryrun_dir: str = "experiments/dryrun") -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    files = sorted(glob.glob(f"{dryrun_dir}/*.json"))
    if not files:
        rows.append(("roofline/missing", 0.0,
                     "run: PYTHONPATH=src python -m repro.launch.dryrun "
                     "--all --mesh both"))
        common.emit(rows)
        return rows
    for f in files:
        d = json.load(open(f))
        tag = f"roofline/{d['arch']}/{d['shape']}/{d['mesh']}"
        if d["status"] == "skipped":
            rows.append((tag, 0.0, "skipped_by_design"))
            continue
        if d["status"] != "ok":
            rows.append((tag, 0.0, f"ERROR {d.get('error','')[:60]}"))
            continue
        r = d["roofline"]
        t_total = max(r["t_compute_s"],
                      r.get("t_memory_min_s", r["t_memory_s"]),
                      r["t_collective_s"])
        rows.append((
            tag, t_total * 1e6,
            f"bneck={r['bottleneck']} tc={r['t_compute_s']:.3f} "
            f"tmem=[{r.get('t_memory_min_s', 0):.3f},{r['t_memory_s']:.3f}] "
            f"tcoll={r['t_collective_s']:.3f} useful="
            f"{r['useful_flops_ratio']:.3f} frac={r['roofline_fraction']:.4f} "
            f"hbm={d['memory']['per_device_hbm_gib']}GiB"))
    common.emit(rows)
    return rows


if __name__ == "__main__":
    main()
