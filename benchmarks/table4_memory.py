"""Paper Table 4: RAM/flash — interpreter (TFLM) vs EON-compiled, float
vs int8 — plus the measured JAX analogue (eager op-by-op dispatch vs AOT
executable) that grounds the "remove the interpreter" claim.
"""
from __future__ import annotations

from typing import List, Tuple

import numpy as np

from benchmarks import common
from repro.core import estimator as est
from repro.core.eon_compiler import compile_impulse, measure_dispatch_overhead


def main() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    tasks = {
        "kws": common.trained_kws_impulse(),
        "vww": common.vww_impulse(),
        "ic": common.ic_impulse(),
    }
    for task, imp in tasks.items():
        for engine in ("tflm", "eon"):
            for int8 in (False, True):
                e = est.estimate_impulse(imp, "nano33ble", engine=engine,
                                         int8=int8)
                tag = f"{engine}/{'int8' if int8 else 'float'}"
                rows.append((f"table4/{task}/{tag}", 0.0,
                             f"ram={e.ram_kb:.1f}kB flash={e.flash_kb:.1f}kB"))
        # measured interpreter-vs-AOT on this host
        if isinstance(imp.input_shape, int):
            raw = np.random.RandomState(0).randn(
                1, imp.input_shape).astype(np.float32)
        else:
            raw = np.random.RandomState(0).randn(
                1, *imp.input_shape).astype(np.float32)
        ov = measure_dispatch_overhead(lambda x: imp.logits(x), raw, iters=5)
        rows.append((f"table4/{task}/measured/eager", ov["eager_us"],
                     "op-by-op dispatch (interpreter analogue)"))
        rows.append((f"table4/{task}/measured/aot", ov["aot_us"],
                     f"AOT executable ({ov['speedup']:.1f}x faster)"))
        art = compile_impulse(imp, batch_size=1)
        rows.append((f"table4/{task}/artifact_bytes",
                     float(art.artifact_bytes), "serialized executable"))
    common.emit(rows)
    return rows


if __name__ == "__main__":
    main()
