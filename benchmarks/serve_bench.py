"""Serving benchmark: static vs continuous batching on a mixed-length
synthetic workload (paper §4.6 operationalised).

Both engines run the same greedy decode steps over the same requests —
scheduling is the only variable — so the delta is pure head-of-line
blocking: static batches decode until their slowest member drains,
continuous batching recycles each KV slot the step its request
finishes.  Reports tokens/s and TTFT p50/p95 per engine.

Run:  PYTHONPATH=src python benchmarks/serve_bench.py [--tiny] [--artifact]
"""
from __future__ import annotations

import argparse
import json

import jax
import numpy as np

from repro import configs
from repro.models.params import init_params
from repro.serve.server import ContinuousBatchServer, StaticBatchServer


def mixed_workload(vocab: int, n_requests: int, max_prompt: int,
                   max_new: int, seed: int = 0):
    """Bimodal prompts (short/long) with varied generation budgets — the
    adversarial case for static batching."""
    rng = np.random.RandomState(seed)
    prompts, budgets = [], []
    for i in range(n_requests):
        if i % 2 == 0:
            n = rng.randint(3, max(4, max_prompt // 4))
            b = rng.randint(2, max(3, max_new // 4))
        else:
            n = rng.randint(max_prompt // 2, max_prompt + 1)
            b = rng.randint(max(2, max_new // 2), max_new + 1)
        prompts.append(rng.randint(0, vocab, n).astype(np.int32))
        budgets.append(int(b))
    return prompts, budgets


def run_bench(arch: str = "internlm2-1.8b", *, n_requests: int = 12,
              slots: int = 4, max_prompt: int = 32, max_new: int = 24,
              use_artifact: bool = False, seed: int = 0):
    cfg = configs.get_smoke(arch)
    params = init_params(cfg, jax.random.key(0))
    prompts, budgets = mixed_workload(cfg.vocab_size, n_requests,
                                      max_prompt, max_new, seed)

    static = StaticBatchServer(cfg, params, batch_size=slots,
                               prompt_len=max_prompt, max_new_tokens=max_new)
    static.submit(prompts, max_new_tokens=budgets)
    m_static = static.run()

    cont = ContinuousBatchServer(
        cfg, params, slots=slots,
        buckets=(max_prompt // 4, max_prompt // 2, max_prompt),
        max_new_tokens=max_new, use_artifact=use_artifact)
    c_reqs = cont.submit(prompts, max_new_tokens=budgets)
    m_cont = cont.run()

    # same scheduling-independent outputs → the speedup is real, not a
    # different (cheaper) computation
    s_reqs = list(static.requests.values())
    tokens_match = ([r.tokens for r in s_reqs]
                    == [cont.requests[i].tokens for i in
                        sorted(cont.requests)])
    assert tokens_match or cfg.family in ("ssm", "hybrid"), \
        "engines diverged on an attention arch"

    speedup = m_cont["tokens_per_s"] / max(m_static["tokens_per_s"], 1e-9)
    report = {"arch": arch, "requests": n_requests, "slots": slots,
              "tokens_match": bool(tokens_match),
              "static": m_static, "continuous": m_cont,
              "tokens_per_s_speedup": speedup}
    return report


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--artifact", action="store_true")
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-sized run for scripts/smoke.sh")
    args = ap.parse_args(argv)
    if args.tiny:
        args.requests, args.slots = 6, 2
        args.max_prompt, args.max_new = 16, 8

    rep = run_bench(args.arch, n_requests=args.requests, slots=args.slots,
                    max_prompt=args.max_prompt, max_new=args.max_new,
                    use_artifact=args.artifact)
    print(json.dumps(rep, indent=1))
    s, c = rep["static"], rep["continuous"]
    print(f"\nstatic     : {s['tokens_per_s']:9.1f} tok/s  "
          f"ttft p50 {s['ttft_p50_s'] * 1e3:7.1f} ms  "
          f"p95 {s['ttft_p95_s'] * 1e3:7.1f} ms  "
          f"decode_steps {s['decode_steps']}")
    print(f"continuous : {c['tokens_per_s']:9.1f} tok/s  "
          f"ttft p50 {c['ttft_p50_s'] * 1e3:7.1f} ms  "
          f"p95 {c['ttft_p95_s'] * 1e3:7.1f} ms  "
          f"decode_steps {c['decode_steps']}  "
          f"slot_util {c.get('slot_utilization', 0):.2f}")
    print(f"speedup    : {rep['tokens_per_s_speedup']:.2f}x tokens/s")


if __name__ == "__main__":
    main()
