"""Serving benchmark: static vs continuous batching × float vs int8
precision × prefill chunk size on a mixed-length synthetic workload
(paper §4.6 + C5 operationalised).

Engines: both run the same greedy decode steps over the same requests —
scheduling is the only variable — so the delta is pure head-of-line
blocking.  Precision: ``--precision int8`` additionally serves the same
seeded workload through the end-to-end int8 path (QTensor weights,
dynamic activation quant, Int8KV cache) and reports tokens/s and
KV-cache HBM bytes side by side with the float baseline — Table 4's
RAM story transposed to the serving tier.  The precision comparison
runs f32 activations (the paper's C5 baseline is float32; bf16 is
emulated on CPU anyway), so the HBM reduction is the honest f32→int8
ratio.

Chunking: ``--prefill-chunk 4 8 16`` sweeps the chunked pad-free
admission axis on the continuous engine — TTFT p50/p95 and the
``kv_read_frac``/``kv_fill_frac`` decode-bandwidth metrics per chunk
size, next to an *estimated* padded-baseline fill (what the retired
left-pad bucket ladder ``(max/4, max/2, max)`` would have kept live:
pad rows sat inside ``kv_len`` and were read every decode step).  The
measured read-fraction drop versus that estimate is the bandwidth the
pad rows used to burn.

The workload generator is seeded (``--seed``) and built ONCE per run:
float-vs-int8, continuous-vs-static, and every chunk size all serve the
identical request mix, so every ratio in the report is apples-to-apples.

Paging: ``--paged`` adds the paged-pool axis — the contiguous engine
vs ``PagedBatchServer`` (block-table memory manager, docs/paged_kv.md)
on a shared-prefix workload, reporting pool utilization (live / total
blocks), prefix-cache hit rate, preemption count, and live-KV HBM
against the contiguous ``slots × capacity`` rectangle; ``--pool-frac``
sizes the pool below the rectangle to force preempt-and-recompute.

Run:  PYTHONPATH=src python benchmarks/serve_bench.py [--tiny]
          [--artifact] [--precision {float,int8}] [--seed N]
          [--prefill-chunk C ...] [--paged [--pool-frac F]]
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import numpy as np

from repro import configs
from repro.models.params import init_params
from repro.serve.server import (ContinuousBatchServer, PagedBatchServer,
                                StaticBatchServer)


def shared_prefix_workload(vocab: int, n_requests: int, max_prompt: int,
                           max_new: int, seed: int = 0):
    """Mixed-length workload where every even request opens with one
    common prompt prefix (half the max prompt) — the paged engine's
    prefix cache should serve those blocks once; the contiguous engine
    recomputes and re-stores them per slot.  Seed-determined."""
    rng = np.random.RandomState(seed + 17)
    plen = max(max_prompt // 2, 1)
    prefix = rng.randint(0, vocab, plen).astype(np.int32)
    prompts, budgets = [], []
    for i in range(n_requests):
        if i % 2 == 0:
            n = rng.randint(1, max(2, max_prompt - plen + 1))
            p = np.concatenate([prefix,
                                rng.randint(0, vocab, n).astype(np.int32)])
        else:
            p = rng.randint(0, vocab,
                            rng.randint(3, max_prompt + 1)).astype(np.int32)
        prompts.append(p)
        budgets.append(int(rng.randint(2, max_new + 1)))
    return prompts, budgets


def mixed_workload(vocab: int, n_requests: int, max_prompt: int,
                   max_new: int, seed: int = 0):
    """Bimodal prompts (short/long) with varied generation budgets — the
    adversarial case for static batching.  Fully determined by ``seed``."""
    rng = np.random.RandomState(seed)
    prompts, budgets = [], []
    for i in range(n_requests):
        if i % 2 == 0:
            n = rng.randint(3, max(4, max_prompt // 4))
            b = rng.randint(2, max(3, max_new // 4))
        else:
            n = rng.randint(max_prompt // 2, max_prompt + 1)
            b = rng.randint(max(2, max_new // 2), max_new + 1)
        prompts.append(rng.randint(0, vocab, n).astype(np.int32))
        budgets.append(int(b))
    return prompts, budgets


def _padded_fill_frac_est(server, chunk_metrics) -> float:
    """What ``kv_fill_frac`` would have been under the retired left-pad
    bucket ladder ``(max/4, max/2, max)``: each request's slot carried
    ``bucket(S) − S`` pad rows inside ``kv_len`` for every decode step
    it was live (≈ its generated-token count)."""
    buckets = sorted({max(server.max_prompt // 4, 1),
                      max(server.max_prompt // 2, 1), server.max_prompt})

    def bucket(n):
        for b in buckets:
            if n <= b:
                return b
        return buckets[-1]

    extra = sum((bucket(len(r.prompt)) - len(r.prompt)) * len(r.tokens)
                for r in server.requests.values())
    denom = (chunk_metrics["decode_steps"] * server.n_slots
             * server.capacity)
    return chunk_metrics.get("kv_fill_frac", 0.0) + extra / max(denom, 1)


def _run_engines(cfg, params, prompts, budgets, *, slots, max_prompt,
                 max_new, use_artifact, precision, prefill_chunk=8):
    static = StaticBatchServer(cfg, params, batch_size=slots,
                               max_prompt=max_prompt,
                               prefill_chunk=prefill_chunk,
                               max_new_tokens=max_new, precision=precision)
    static.submit(prompts, max_new_tokens=budgets)
    m_static = static.run()

    cont = ContinuousBatchServer(
        cfg, params, slots=slots, max_prompt=max_prompt,
        prefill_chunk=prefill_chunk, max_new_tokens=max_new,
        use_artifact=use_artifact, precision=precision)
    cont.submit(prompts, max_new_tokens=budgets)
    m_cont = cont.run()
    m_cont["padded_fill_frac_est"] = _padded_fill_frac_est(cont, m_cont)

    # same scheduling-independent outputs → the speedup is real, not a
    # different (cheaper) computation.  Pad-free chunked prefill makes
    # this exact for EVERY family — SSM/hybrid recurrences included.
    s_reqs = list(static.requests.values())
    tokens_match = ([r.tokens for r in s_reqs]
                    == [cont.requests[i].tokens for i in
                        sorted(cont.requests)])
    assert tokens_match, f"engines diverged ({cfg.name}, {precision})"
    return {"static": m_static, "continuous": m_cont,
            "tokens_match": bool(tokens_match),
            "tokens_per_s_speedup": (m_cont["tokens_per_s"]
                                     / max(m_static["tokens_per_s"], 1e-9))}


def _run_paged(cfg, params, *, slots, max_prompt, max_new, precision,
               pool_frac, n_requests, seed, prefill_chunk=8):
    """Paged-pool axis: contiguous vs paged engine on a shared-prefix
    mixed-length workload (same requests, token-exactness asserted).

    The paged server runs with block_size 8 (fine-grained pooling so
    the tiny bench actually exercises tables/sharing) and a pool of
    ``pool_frac`` × the contiguous rectangle's blocks — under 1.0 the
    engine must preempt-and-recompute to stay correct, which the report
    counts.  Reported: tokens/s both engines, pool utilization (live /
    total blocks), prefix-cache hit rate, and live-KV HBM vs the
    contiguous ``slots × capacity`` rectangle."""
    prompts, budgets = shared_prefix_workload(
        cfg.vocab_size, n_requests, max_prompt, max_new, seed)
    cont = ContinuousBatchServer(
        cfg, params, slots=slots, max_prompt=max_prompt,
        prefill_chunk=prefill_chunk, max_new_tokens=max_new,
        precision=precision)
    cont.submit(prompts, max_new_tokens=budgets)
    m_cont = cont.run()

    bs = 8
    n_rect = slots * cont.capacity // bs
    pool = max(int(pool_frac * n_rect), cont.capacity // bs)
    paged = PagedBatchServer(
        cfg, params, slots=slots, max_prompt=max_prompt,
        prefill_chunk=prefill_chunk, max_new_tokens=max_new,
        precision=precision, block_size=bs, pool_blocks=pool)
    paged.submit(prompts, max_new_tokens=budgets)
    m_paged = paged.run()

    # same tokens out of both engines — paging, sharing, and preemption
    # are pure memory-management concerns, never visible in the stream
    tokens_match = ([r.tokens for r in cont.requests.values()]
                    == [paged.requests[i].tokens
                        for i in sorted(paged.requests)])
    assert tokens_match, f"paged engine diverged ({cfg.name}, {precision})"
    baseline = m_cont["kv_cache_bytes"]
    return {
        "contiguous": m_cont, "paged": m_paged,
        "tokens_match": bool(tokens_match),
        "tokens_per_s_ratio": (m_paged["tokens_per_s"]
                               / max(m_cont["tokens_per_s"], 1e-9)),
        "kv_rect_bytes": baseline,
        "kv_live_bytes_peak": m_paged.get("kv_live_bytes_peak", 0),
        "kv_live_vs_rect": (m_paged.get("kv_live_bytes_peak", 0)
                            / max(baseline, 1)),
    }


def _run_chunk_axis(cfg, params, prompts, budgets, *, slots, max_prompt,
                    max_new, precision, chunks):
    """Continuous engine only, one run per chunk size, same workload."""
    rows = {}
    for c in chunks:
        cont = ContinuousBatchServer(
            cfg, params, slots=slots, max_prompt=max_prompt,
            prefill_chunk=c, max_new_tokens=max_new, precision=precision)
        cont.submit(prompts, max_new_tokens=budgets)
        m = cont.run()
        m["padded_fill_frac_est"] = _padded_fill_frac_est(cont, m)
        rows[c] = m
    return rows


def run_bench(arch: str = "internlm2-1.8b", *, n_requests: int = 12,
              slots: int = 4, max_prompt: int = 32, max_new: int = 24,
              use_artifact: bool = False, seed: int = 0,
              precision: str = "float", prefill_chunks=None,
              paged_pool_frac=None, paged_only: bool = False):
    cfg = configs.get_smoke(arch)
    if precision == "int8":
        # precision axis: pin f32 activations so the float baseline is
        # the paper's C5 comparison point (and CPU-fast).
        cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    prompts, budgets = mixed_workload(cfg.vocab_size, n_requests,
                                      max_prompt, max_new, seed)

    kw = dict(slots=slots, max_prompt=max_prompt, max_new=max_new,
              use_artifact=use_artifact)
    report = {"arch": arch, "requests": n_requests, "slots": slots,
              "seed": seed, "precision": precision}
    if not paged_only:
        report["float"] = _run_engines(cfg, params, prompts, budgets,
                                       precision="float", **kw)
        if precision == "int8":
            report["int8"] = _run_engines(cfg, params, prompts, budgets,
                                          precision="int8", **kw)
            fb = report["float"]["continuous"]["kv_cache_bytes"]
            qb = report["int8"]["continuous"]["kv_cache_bytes"]
            report["kv_cache_hbm_reduction"] = fb / max(qb, 1)
    if prefill_chunks:
        report["chunk_axis"] = _run_chunk_axis(
            cfg, params, prompts, budgets, slots=slots,
            max_prompt=max_prompt, max_new=max_new, precision=precision,
            chunks=prefill_chunks)
    if paged_pool_frac is not None:
        pkw = dict(slots=slots, max_prompt=max_prompt, max_new=max_new,
                   pool_frac=paged_pool_frac, n_requests=n_requests,
                   seed=seed)
        report["paged"] = {"float": _run_paged(cfg, params,
                                               precision="float", **pkw)}
        if precision == "int8":
            report["paged"]["int8"] = _run_paged(cfg, params,
                                                 precision="int8", **pkw)
    if not paged_only:
        # legacy top-level keys (float engine comparison)
        report.update({k: report["float"][k] for k in
                       ("static", "continuous", "tokens_match",
                        "tokens_per_s_speedup")})
    return report


def _decode_hbm_note(res, tag):
    """Per-decode-step KV HBM bytes: the full slots × capacity rectangle
    vs what the kv_len-bounded flash-decode kernel reads (exact pad-free
    fill, whole KV blocks).  Wall-clock effect needs TPU; the byte
    estimate prices full-attention KV leaves — window-bounded ring
    caches are carried at the same fraction as an approximation."""
    c = res["continuous"]
    full = c.get("kv_cache_bytes", 0)
    frac = c.get("kv_read_frac")
    if not full or frac is None:
        return None
    pad = c.get("padded_fill_frac_est")
    pad_note = (f"; padded-baseline fill est {pad:.1%}"
                if pad is not None else "")
    return (f"[{tag}] decode-step KV read: full-capacity scan {full:,} B"
            f" → kv_len-bounded {int(full * frac):,} B"
            f" ({frac:.0%} of capacity at kernel-block granularity;"
            f" exact pad-free fill {c.get('kv_fill_frac', 0):.1%}"
            f"{pad_note})")


def _print_engine_lines(tag, res):
    s, c = res["static"], res["continuous"]
    print(f"[{tag}] static     : {s['tokens_per_s']:9.1f} tok/s  "
          f"ttft p50 {s['ttft_p50_s'] * 1e3:7.1f} ms  "
          f"p95 {s['ttft_p95_s'] * 1e3:7.1f} ms  "
          f"decode_steps {s['decode_steps']}")
    print(f"[{tag}] continuous : {c['tokens_per_s']:9.1f} tok/s  "
          f"ttft p50 {c['ttft_p50_s'] * 1e3:7.1f} ms  "
          f"p95 {c['ttft_p95_s'] * 1e3:7.1f} ms  "
          f"decode_steps {c['decode_steps']}  "
          f"slot_util {c.get('slot_utilization', 0):.2f}  "
          f"kv_hbm {c.get('kv_cache_bytes', 0):,} B")
    print(f"[{tag}] speedup    : {res['tokens_per_s_speedup']:.2f}x tokens/s")


def _print_paged(tag, res):
    c, p = res["contiguous"], res["paged"]
    print(f"[{tag}] contiguous : {c['tokens_per_s']:9.1f} tok/s  "
          f"kv_hbm {c['kv_cache_bytes']:,} B (slots × capacity rectangle)")
    print(f"[{tag}] paged      : {p['tokens_per_s']:9.1f} tok/s  "
          f"pool {p['pool_blocks']}×{p['block_size']}  "
          f"util {p.get('pool_utilization', 0):.2f}  "
          f"live-KV peak {res['kv_live_bytes_peak']:,} B "
          f"({res['kv_live_vs_rect']:.0%} of rectangle)  "
          f"prefix-hit {p['prefix_hit_rate']:.0%}  "
          f"preemptions {p['preemptions']}")


def _print_chunk_axis(rows):
    print("\nprefill-chunk axis (continuous engine, same workload):")
    print("  C   tok/s   ttft_p50   ttft_p95   kv_read  kv_fill  "
          "padded_est")
    for c, m in sorted(rows.items()):
        print(f"{c:>3} {m['tokens_per_s']:7.1f} "
              f"{m['ttft_p50_s'] * 1e3:8.1f}ms {m['ttft_p95_s'] * 1e3:8.1f}ms"
              f" {m.get('kv_read_frac', 0):8.0%} "
              f"{m.get('kv_fill_frac', 0):8.1%} "
              f"{m.get('padded_fill_frac_est', 0):8.1%}")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--artifact", action="store_true")
    ap.add_argument("--precision", choices=("float", "int8"),
                    default="float",
                    help="int8 additionally serves the identical workload"
                         " end-to-end int8 and reports the KV-cache HBM"
                         " delta vs float")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload seed (same seed ⇒ identical request mix"
                         " across engines, precisions, and chunk sizes)")
    ap.add_argument("--prefill-chunk", type=int, nargs="+", default=None,
                    help="sweep chunked-admission chunk sizes on the"
                         " continuous engine (TTFT + kv-read/fill per C)")
    ap.add_argument("--paged", action="store_true",
                    help="paged-pool axis: contiguous vs paged engine on"
                         " a shared-prefix workload — pool utilization,"
                         " prefix-hit rate, live-KV HBM vs the rectangle")
    ap.add_argument("--paged-only", action="store_true",
                    help="run ONLY the paged axis (skip the static-vs-"
                         "continuous engine matrix — the paged axis"
                         " builds its own contiguous baseline)")
    ap.add_argument("--pool-frac", type=float, default=0.75,
                    help="paged pool size as a fraction of the contiguous"
                         " slots × capacity rectangle (< 1.0 forces"
                         " preempt-and-recompute under load)")
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-sized run for scripts/smoke.sh")
    args = ap.parse_args(argv)
    if args.tiny:
        args.requests, args.slots = 6, 2
        args.max_prompt, args.max_new = 16, 8

    paged = args.paged or args.paged_only
    rep = run_bench(args.arch, n_requests=args.requests, slots=args.slots,
                    max_prompt=args.max_prompt, max_new=args.max_new,
                    use_artifact=args.artifact, seed=args.seed,
                    precision=args.precision,
                    prefill_chunks=args.prefill_chunk,
                    paged_pool_frac=args.pool_frac if paged else None,
                    paged_only=args.paged_only)
    print(json.dumps(rep, indent=1))
    print()
    if "float" in rep:
        _print_engine_lines("float", rep["float"])
        note = _decode_hbm_note(rep["float"], "float")
        if note:
            print(note)
    if "int8" in rep:
        _print_engine_lines("int8 ", rep["int8"])
        note = _decode_hbm_note(rep["int8"], "int8 ")
        if note:
            print(note)
        print(f"\nkv-cache HBM: float "
              f"{rep['float']['continuous']['kv_cache_bytes']:,} B  →  int8 "
              f"{rep['int8']['continuous']['kv_cache_bytes']:,} B  "
              f"({rep['kv_cache_hbm_reduction']:.2f}x reduction)")
    if "chunk_axis" in rep:
        _print_chunk_axis(rep["chunk_axis"])
    if "paged" in rep:
        print("\npaged-pool axis (shared-prefix workload, block-table"
              " memory manager):")
        for tag, res in rep["paged"].items():
            _print_paged(f"paged/{tag}", res)


if __name__ == "__main__":
    main()
