"""Serving benchmark: static vs continuous batching × float vs int8
precision on a mixed-length synthetic workload (paper §4.6 + C5
operationalised).

Engines: both run the same greedy decode steps over the same requests —
scheduling is the only variable — so the delta is pure head-of-line
blocking.  Precision: ``--precision int8`` additionally serves the same
seeded workload through the end-to-end int8 path (QTensor weights,
dynamic activation quant, Int8KV cache) and reports tokens/s and
KV-cache HBM bytes side by side with the float baseline — Table 4's
RAM story transposed to the serving tier.  The precision comparison
runs f32 activations (the paper's C5 baseline is float32; bf16 is
emulated on CPU anyway), so the HBM reduction is the honest f32→int8
ratio.

The workload generator is seeded (``--seed``) and built ONCE per run:
float-vs-int8 and continuous-vs-static all serve the identical request
mix, so every ratio in the report is apples-to-apples.

Run:  PYTHONPATH=src python benchmarks/serve_bench.py [--tiny]
          [--artifact] [--precision {float,int8}] [--seed N]
"""
from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import numpy as np

from repro import configs
from repro.models.params import init_params
from repro.serve.server import ContinuousBatchServer, StaticBatchServer


def mixed_workload(vocab: int, n_requests: int, max_prompt: int,
                   max_new: int, seed: int = 0):
    """Bimodal prompts (short/long) with varied generation budgets — the
    adversarial case for static batching.  Fully determined by ``seed``."""
    rng = np.random.RandomState(seed)
    prompts, budgets = [], []
    for i in range(n_requests):
        if i % 2 == 0:
            n = rng.randint(3, max(4, max_prompt // 4))
            b = rng.randint(2, max(3, max_new // 4))
        else:
            n = rng.randint(max_prompt // 2, max_prompt + 1)
            b = rng.randint(max(2, max_new // 2), max_new + 1)
        prompts.append(rng.randint(0, vocab, n).astype(np.int32))
        budgets.append(int(b))
    return prompts, budgets


def _run_engines(cfg, params, prompts, budgets, *, slots, max_prompt,
                 max_new, use_artifact, precision):
    static = StaticBatchServer(cfg, params, batch_size=slots,
                               prompt_len=max_prompt, max_new_tokens=max_new,
                               precision=precision)
    static.submit(prompts, max_new_tokens=budgets)
    m_static = static.run()

    cont = ContinuousBatchServer(
        cfg, params, slots=slots,
        buckets=(max_prompt // 4, max_prompt // 2, max_prompt),
        max_new_tokens=max_new, use_artifact=use_artifact,
        precision=precision)
    cont.submit(prompts, max_new_tokens=budgets)
    m_cont = cont.run()

    # same scheduling-independent outputs → the speedup is real, not a
    # different (cheaper) computation
    s_reqs = list(static.requests.values())
    tokens_match = ([r.tokens for r in s_reqs]
                    == [cont.requests[i].tokens for i in
                        sorted(cont.requests)])
    assert tokens_match or cfg.family in ("ssm", "hybrid"), \
        f"engines diverged on an attention arch ({precision})"
    return {"static": m_static, "continuous": m_cont,
            "tokens_match": bool(tokens_match),
            "tokens_per_s_speedup": (m_cont["tokens_per_s"]
                                     / max(m_static["tokens_per_s"], 1e-9))}


def run_bench(arch: str = "internlm2-1.8b", *, n_requests: int = 12,
              slots: int = 4, max_prompt: int = 32, max_new: int = 24,
              use_artifact: bool = False, seed: int = 0,
              precision: str = "float"):
    cfg = configs.get_smoke(arch)
    if precision == "int8":
        # precision axis: pin f32 activations so the float baseline is
        # the paper's C5 comparison point (and CPU-fast).
        cfg = dataclasses.replace(cfg, dtype="float32")
    params = init_params(cfg, jax.random.key(0))
    prompts, budgets = mixed_workload(cfg.vocab_size, n_requests,
                                      max_prompt, max_new, seed)

    kw = dict(slots=slots, max_prompt=max_prompt, max_new=max_new,
              use_artifact=use_artifact)
    report = {"arch": arch, "requests": n_requests, "slots": slots,
              "seed": seed, "precision": precision}
    report["float"] = _run_engines(cfg, params, prompts, budgets,
                                   precision="float", **kw)
    if precision == "int8":
        report["int8"] = _run_engines(cfg, params, prompts, budgets,
                                      precision="int8", **kw)
        fb = report["float"]["continuous"]["kv_cache_bytes"]
        qb = report["int8"]["continuous"]["kv_cache_bytes"]
        report["kv_cache_hbm_reduction"] = fb / max(qb, 1)
    # legacy top-level keys (float engine comparison)
    report.update({k: report["float"][k] for k in
                   ("static", "continuous", "tokens_match",
                    "tokens_per_s_speedup")})
    return report


def _decode_hbm_note(res, tag):
    """Per-decode-step KV HBM bytes: the full slots × capacity rectangle
    vs what the kv_len-bounded flash-decode kernel reads (scheduler
    fill, whole KV blocks).  Wall-clock effect needs TPU; the byte
    estimate prices full-attention KV leaves — window-bounded ring
    caches are carried at the same fraction as an approximation."""
    c = res["continuous"]
    full = c.get("kv_cache_bytes", 0)
    frac = c.get("kv_read_frac")
    if not full or frac is None:
        return None
    return (f"[{tag}] decode-step KV read: full-capacity scan {full:,} B"
            f" → kv_len-bounded {int(full * frac):,} B"
            f" ({frac:.0%} of capacity at kernel-block granularity;"
            f" raw slot fill {c.get('kv_fill_frac', 0):.0%})")


def _print_engine_lines(tag, res):
    s, c = res["static"], res["continuous"]
    print(f"[{tag}] static     : {s['tokens_per_s']:9.1f} tok/s  "
          f"ttft p50 {s['ttft_p50_s'] * 1e3:7.1f} ms  "
          f"p95 {s['ttft_p95_s'] * 1e3:7.1f} ms  "
          f"decode_steps {s['decode_steps']}")
    print(f"[{tag}] continuous : {c['tokens_per_s']:9.1f} tok/s  "
          f"ttft p50 {c['ttft_p50_s'] * 1e3:7.1f} ms  "
          f"p95 {c['ttft_p95_s'] * 1e3:7.1f} ms  "
          f"decode_steps {c['decode_steps']}  "
          f"slot_util {c.get('slot_utilization', 0):.2f}  "
          f"kv_hbm {c.get('kv_cache_bytes', 0):,} B")
    print(f"[{tag}] speedup    : {res['tokens_per_s_speedup']:.2f}x tokens/s")


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2-1.8b")
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-prompt", type=int, default=32)
    ap.add_argument("--max-new", type=int, default=24)
    ap.add_argument("--artifact", action="store_true")
    ap.add_argument("--precision", choices=("float", "int8"),
                    default="float",
                    help="int8 additionally serves the identical workload"
                         " end-to-end int8 and reports the KV-cache HBM"
                         " delta vs float")
    ap.add_argument("--seed", type=int, default=0,
                    help="workload seed (same seed ⇒ identical request mix"
                         " across engines and precisions)")
    ap.add_argument("--tiny", action="store_true",
                    help="smoke-sized run for scripts/smoke.sh")
    args = ap.parse_args(argv)
    if args.tiny:
        args.requests, args.slots = 6, 2
        args.max_prompt, args.max_new = 16, 8

    rep = run_bench(args.arch, n_requests=args.requests, slots=args.slots,
                    max_prompt=args.max_prompt, max_new=args.max_new,
                    use_artifact=args.artifact, seed=args.seed,
                    precision=args.precision)
    print(json.dumps(rep, indent=1))
    print()
    _print_engine_lines("float", rep["float"])
    note = _decode_hbm_note(rep["float"], "float")
    if note:
        print(note)
    if "int8" in rep:
        _print_engine_lines("int8 ", rep["int8"])
        note = _decode_hbm_note(rep["int8"], "int8 ")
        if note:
            print(note)
        print(f"\nkv-cache HBM: float "
              f"{rep['float']['continuous']['kv_cache_bytes']:,} B  →  int8 "
              f"{rep['int8']['continuous']['kv_cache_bytes']:,} B  "
              f"({rep['kv_cache_hbm_reduction']:.2f}x reduction)")


if __name__ == "__main__":
    main()
