"""Paper §4.4 performance calibration: GA-tuned post-processing Pareto
front (FAR vs FRR) on a synthetic detector stream."""
from __future__ import annotations

import time
from typing import List, Tuple

from benchmarks import common
from repro.core.calibration import calibrate
from repro.data.synthetic import event_stream


def main() -> List[Tuple[str, float, str]]:
    scores, spans = event_stream(n_windows=20_000, n_events=60, seed=0)
    t0 = time.perf_counter()
    front = calibrate(scores, spans, generations=10, population=24)
    dt_us = (time.perf_counter() - t0) * 1e6
    rows: List[Tuple[str, float, str]] = [
        ("calibration/ga_search", dt_us, f"front_size={len(front)}")]
    for i, p in enumerate(front):
        c = p["config"]
        rows.append((
            f"calibration/front_{i}", 0.0,
            f"far={p['far_per_hour']:.1f}/h frr={p['frr']:.3f} "
            f"smooth={c['smooth_window']} thr={c['threshold']:.2f} "
            f"suppress={c['suppression']}"))
    common.emit(rows)
    return rows


if __name__ == "__main__":
    main()
