"""Kernel microbenchmarks: ref-path timings on this host + the shapes
the Pallas kernels tile for on TPU (correctness is tests/test_kernels.py;
wall-clock Pallas numbers require real hardware)."""
from __future__ import annotations

from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks import common
from repro.kernels import ops


def main() -> List[Tuple[str, float, str]]:
    rows: List[Tuple[str, float, str]] = []
    rng = np.random.RandomState(0)

    # int8 matmul vs float matmul (serving path)
    m, k, n = 256, 1024, 1024
    xq = jnp.asarray(rng.randint(-127, 128, (m, k)), jnp.int8)
    wq = jnp.asarray(rng.randint(-127, 128, (k, n)), jnp.int8)
    xs = jnp.ones((m,), jnp.float32)
    ws = jnp.ones((n,), jnp.float32)
    xf = jnp.asarray(rng.randn(m, k), jnp.float32)
    wf = jnp.asarray(rng.randn(k, n), jnp.float32)
    t_int8 = common.time_call(
        jax.jit(lambda a, b, c, d: ops.int8_matmul(a, b, c, d)),
        xq, wq, xs, ws)
    t_f32 = common.time_call(jax.jit(lambda a, b: a @ b), xf, wf)
    rows.append(("kernel/int8_matmul_ref", t_int8, f"{m}x{k}x{n}"))
    rows.append(("kernel/f32_matmul", t_f32, f"{m}x{k}x{n}"))

    # decode attention (serving hot loop): float vs int8 cache,
    # short-occupancy vs full-capacity kv_len.  On the ref path the
    # bound is a mask (no skip), so the short/full delta is a TPU
    # number; the rows pin the shapes + both precisions either way.
    from repro.core.quantize import quant_kv
    slots, cap, hq, hkv, hd = 4, 2048, 8, 2, 64
    dq = jnp.asarray(rng.randn(slots, 1, hq, hd), jnp.float32)
    dk = jnp.asarray(rng.randn(slots, cap, hkv, hd), jnp.float32)
    dv = jnp.asarray(rng.randn(slots, cap, hkv, hd), jnp.float32)
    dpos = jnp.broadcast_to(jnp.arange(cap, dtype=jnp.int32), (slots, cap))
    dqp = jnp.full((slots,), cap - 1, jnp.int32)
    kv_full = jnp.full((slots,), cap, jnp.int32)
    kv_short = jnp.full((slots,), cap // 8, jnp.int32)
    dk8, dv8 = quant_kv(dk), quant_kv(dv)
    for tag, kk_, vv_ in (("float", dk, dv), ("int8", dk8, dv8)):
        for occ, kvl in (("full", kv_full), ("short", kv_short)):
            t = common.time_call(
                jax.jit(lambda q_, k_, v_, kl: ops.decode_attention(
                    q_, k_, v_, dqp, dpos, kv_len=kl)),
                dq, kk_, vv_, kvl)
            rows.append((f"kernel/decode_attn_{tag}_{occ}", t,
                         f"slots={slots} cap={cap} kv_len={int(kvl[0])} "
                         f"Hq/Hkv={hq}/{hkv}"))

    # chunk-prefill attention (serving admission path): C chunk queries
    # against the live slot prefix — one compiled shape regardless of
    # prompt length, kv_len-bounded like decode.
    c = 16
    cq = jnp.asarray(rng.randn(slots, c, hq, hd), jnp.float32)
    cqp = jnp.broadcast_to(jnp.arange(cap // 8 - c, cap // 8,
                                      dtype=jnp.int32), (slots, c))
    kv_chunk = jnp.full((slots,), cap // 8, jnp.int32)
    for tag, kk_, vv_ in (("float", dk, dv), ("int8", dk8, dv8)):
        t = common.time_call(
            jax.jit(lambda q_, k_, v_, kl: ops.chunk_attention(
                q_, k_, v_, cqp, dpos, kv_len=kl)),
            cq, kk_, vv_, kv_chunk)
        rows.append((f"kernel/chunk_prefill_attn_{tag}", t,
                     f"slots={slots} cap={cap} C={c} "
                     f"kv_len={int(kv_chunk[0])} Hq/Hkv={hq}/{hkv}"))

    # flash attention ref vs naive full attention
    b, s, h, d = 1, 2048, 4, 64
    q = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
    kk = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
    v = jnp.asarray(rng.randn(b, s, h, d), jnp.float32)
    t_ref = common.time_call(
        jax.jit(lambda a, b_, c: ops.flash_attention(a, b_, c)), q, kk, v)
    rows.append(("kernel/flash_attention_ref", t_ref, f"S={s} D={d}"))

    # mamba scan ref
    bs, ss, dd, nn = 1, 1024, 256, 16
    x = jnp.asarray(rng.randn(bs, ss, dd), jnp.float32) * 0.3
    dt = jax.nn.softplus(jnp.asarray(rng.randn(bs, ss, dd), jnp.float32))
    bm = jnp.asarray(rng.randn(bs, ss, nn), jnp.float32) * 0.3
    cm = jnp.asarray(rng.randn(bs, ss, nn), jnp.float32) * 0.3
    a = -jnp.exp(jnp.asarray(rng.randn(dd, nn), jnp.float32) * 0.2)
    t_scan = common.time_call(
        jax.jit(lambda *args: ops.mamba_scan(*args)[0]), x, dt, bm, cm, a)
    rows.append(("kernel/mamba_scan_ref", t_scan, f"S={ss} D={dd} N={nn}"))

    # mel frontend
    frames = jnp.asarray(rng.randn(128, 512), jnp.float32)
    from repro.dsp import filterbank as fb
    window = jnp.asarray(np.hanning(512), jnp.float32)
    cos, sin = fb.dft_matrices(512)
    mel = jnp.asarray(fb.mel_filterbank(257, 40, 16000))
    t_mel = common.time_call(
        jax.jit(lambda f: ops.mel_frontend(f, window, jnp.asarray(cos),
                                           jnp.asarray(sin), mel)), frames)
    rows.append(("kernel/mel_frontend_ref", t_mel, "128 frames x 512"))
    common.emit(rows)
    return rows


if __name__ == "__main__":
    main()
