"""Shared benchmark plumbing: timed calls + the trained KWS/VWW/IC
impulses the paper's tables revolve around."""
from __future__ import annotations

import time
from typing import Callable, Dict, List, Tuple

import jax
import numpy as np

from repro.core.blocks import make_dsp_block, make_learn_block
from repro.core.impulse import Impulse
from repro.data.dataset import Dataset
from repro.data.synthetic import keyword_audio

KWS_SAMPLES = 8000


def time_call(fn: Callable, *args, iters: int = 10, warmup: int = 2
              ) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6  # us


def kws_dataset() -> Dataset:
    ds = Dataset()
    ds.add_many(keyword_audio(n_per_class=24, n_classes=4,
                              n_samples=KWS_SAMPLES, seed=0))
    return ds


def trained_kws_impulse(ds: Dataset = None, epochs: int = 5) -> Impulse:
    ds = ds or kws_dataset()
    imp = Impulse(make_dsp_block("mfcc", n_mels=32, n_coeffs=10),
                  make_learn_block("conv1d-stack", n_blocks=2, ch_first=16,
                                   ch_last=64, n_classes=4),
                  input_shape=KWS_SAMPLES)
    imp.init(jax.random.key(0))
    xtr, ytr = ds.arrays("train")
    imp.fit((np.asarray(xtr), np.asarray(ytr)), epochs=epochs,
            batch_size=16, lr=2e-3)
    imp.quantize(np.asarray(xtr[:16]))
    return imp


def vww_impulse() -> Impulse:
    """MobileNetV1-0.25 on 64x64x3 (structure benchmark; not trained)."""
    imp = Impulse(make_dsp_block("image_norm"),
                  make_learn_block("mobilenetv1", n_classes=2,
                                   width_mult=0.25),
                  input_shape=(64, 64, 3))
    return imp.init(jax.random.key(1))


def ic_impulse() -> Impulse:
    """CIFAR CNN on 32x32x3 (structure benchmark; not trained)."""
    imp = Impulse(make_dsp_block("image_norm"),
                  make_learn_block("cifar-cnn", n_classes=10),
                  input_shape=(32, 32, 3))
    return imp.init(jax.random.key(2))


def emit(rows: List[Tuple[str, float, str]]) -> None:
    for name, us, derived in rows:
        print(f"{name},{us:.1f},{derived}")
