"""Benchmark aggregator: one module per paper table + framework extras.

Prints ``name,us_per_call,derived`` CSV rows (per deliverable spec).
"""
from __future__ import annotations

import sys
import traceback


def main() -> None:
    from benchmarks import (calibration_bench, kernel_bench,
                            roofline_report, table2_inference_times,
                            table3_eon_tuner, table4_memory)
    suites = [
        ("table2_inference_times", table2_inference_times.main),
        ("table3_eon_tuner", table3_eon_tuner.main),
        ("table4_memory", table4_memory.main),
        ("calibration_bench", calibration_bench.main),
        ("kernel_bench", kernel_bench.main),
        ("roofline_report", roofline_report.main),
    ]
    print("name,us_per_call,derived")
    failed = []
    for name, fn in suites:
        try:
            fn()
        except Exception:
            failed.append(name)
            traceback.print_exc()
    if failed:
        print(f"FAILED suites: {failed}", file=sys.stderr)
        raise SystemExit(1)


if __name__ == "__main__":
    main()
